"""Dry-run machinery tests: HLO cost analyzer, policies, cell wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost
from repro.launch.hlo_analysis import collective_stats, shape_bytes
from repro.launch.specs import runnable
from repro.configs import get_config
from repro.models.config import SHAPES
from repro.models.params import ShardingRules, opt_spec_for, ParamDef
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------- hlo parsing
def test_shape_bytes():
    assert shape_bytes("bf16[16,256]{1,0}") == 16 * 256 * 2
    assert shape_bytes("(f32[8], s32[4])") == 8 * 4 + 4 * 4
    assert shape_bytes("pred[]") == 1


def test_loop_aware_flops_exact():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, x).compile()
    costs = hlo_cost.analyze(comp.as_text())
    assert costs.flops == pytest.approx(7 * 2 * 64**3, rel=1e-6)


def test_nested_loop_flops_exact():
    def g(x, w):
        def outer(c, _):
            def inner(d, _):
                return jnp.tanh(d @ w), None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(g).lower(x, x).compile()
    costs = hlo_cost.analyze(comp.as_text())
    assert costs.flops == pytest.approx(15 * 2 * 32**3, rel=1e-6)


def test_flops_vs_analytic_model_flops():
    """Compiled (loop-corrected) flops for a tiny LM must land within 2x of
    the 6*N*D + attention analytic estimate (fwd+bwd+remat ~ 8*N*D)."""
    from repro.models import build

    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    B, S = 2, 64
    batch = {
        "inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }

    def loss_grad(params, batch):
        return jax.value_and_grad(lambda p: model.loss(p, batch))(params)

    comp = jax.jit(loss_grad).lower(model.abstract(), batch).compile()
    costs = hlo_cost.analyze(comp.as_text())
    analytic = 8.0 * model.n_params * B * S     # fwd 2 + bwd 4 + remat 2
    assert costs.flops > 0.3 * analytic
    assert costs.flops < 3.0 * analytic


def test_collective_stats_counts():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %ag = f32[16] all-gather(%p), replica_groups={}
  %ar.1 = f32[8] all-reduce(%p), to_apply=%add
  %cp-start = f32[8] collective-permute-start(%p)
  %cp-done = f32[8] collective-permute-done(%cp-start)
}
"""
    stats = collective_stats(hlo)
    assert stats.bytes_by_kind["all-gather"] == 64
    assert stats.bytes_by_kind["all-reduce"] == 32
    assert stats.bytes_by_kind["collective-permute"] == 32
    assert "collective-permute" in stats.count_by_kind


# ---------------------------------------------------------------- policies
def test_runnable_matrix():
    n_run = n_skip = 0
    from repro.configs import ARCH_IDS

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = runnable(cfg, shape)
            if ok:
                n_run += 1
            else:
                n_skip += 1
                assert shape.name == "long_500k"
                assert "sub-quadratic" in why
    assert n_run == 33 and n_skip == 7   # 40 cells, 7 long_500k skips


def test_sharding_rules_tp_divisibility():
    rules = ShardingRules(mode="tp", model_size=16)
    d = ParamDef((2048, 8192), ("embed", "ffn"))
    assert rules.spec_for(d) == P(None, "model")
    # non-divisible fused dim falls back to embed (row) sharding
    d2 = ParamDef((2048, 28 * 128), ("embed", "q_fused"))
    assert rules.spec_for(d2) == P(None, "model")  # 3584 divisible
    d3 = ParamDef((30, 577), ("layers", "q_fused"))
    assert rules.spec_for(d3) == P(None, None)


def test_opt_spec_adds_data_axis():
    rules = ShardingRules(mode="fsdp", model_size=16, data_size=16)
    d = ParamDef((4096, 4096), ("embed", "ffn"))
    base = rules.spec_for(d)
    opt = opt_spec_for(d, rules)
    assert base == P("model", None)
    assert opt == P("model", "data")     # ZeRO-1: moments shard further


def test_choose_microbatches_scaling():
    from repro.launch.mesh import small_mesh
    from repro.launch.steps import choose_microbatches

    mesh = small_mesh(("data", "model"), (1, 1))
    cfg = get_config("pixtral-12b")
    n = choose_microbatches(cfg, SHAPES["train_4k"], mesh)
    assert n >= 1 and SHAPES["train_4k"].global_batch % n == 0
