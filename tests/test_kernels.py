"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode on CPU (the TPU lowering is exercised by
the same pallas_call on real hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.stencil import stencil_pallas
from repro.kernels.wkv6 import wkv6_pallas


# fp32 tolerance covers blocked-vs-flat accumulation order at k ~ 512.
TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _assert_close(out, expect, dtype):
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **TOL[dtype],
    )


# ------------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 512, 256), (384, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    a = jax.random.normal(jax.random.key(0), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.key(1), (k, n)).astype(dtype)
    out = matmul_pallas(a, b, interpret=True)
    _assert_close(out, ref.matmul(a, b), dtype)


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 64),
                                      (64, 128, 128)])
def test_matmul_block_sweep(bm, bn, bk):
    a = jax.random.normal(jax.random.key(2), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(3), (256, 256), jnp.float32)
    out = matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    _assert_close(out, ref.matmul(a, b), jnp.float32)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("s,d", [(128, 64), (256, 64), (256, 128)])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_shapes(s, d, window):
    BH = 4
    q = jax.random.normal(jax.random.key(0), (BH, s, d), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (BH, s, d), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (BH, s, d), jnp.float32)
    out = flash_attention_pallas(q, k, v, window=window, bq=64, bk=64,
                                 interpret=True)
    expect = ref.flash_attention(q, k, v, window=window)
    _assert_close(out, expect, jnp.float32)


def test_flash_attention_bf16():
    BH, s, d = 2, 128, 64
    q = jax.random.normal(jax.random.key(0), (BH, s, d)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (BH, s, d)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (BH, s, d)).astype(jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, bq=64, bk=64, interpret=True)
    expect = ref.flash_attention(q, k, v)
    _assert_close(out, expect, jnp.bfloat16)


def test_flash_attention_gqa_wrapper():
    B, S, H, Kv, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, Kv, hd), jnp.float32)
    out = ops.flash_attention(q, k, v)
    from repro.models import layers

    expect = layers.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(
    nq=st.sampled_from([64, 128]),
    window=st.sampled_from([0, 32, 128]),
    seed=st.integers(0, 5),
)
def test_flash_attention_property(nq, window, seed):
    BH, d = 2, 32
    q = jax.random.normal(jax.random.key(seed), (BH, nq, d), jnp.float32)
    k = jax.random.normal(jax.random.key(seed + 1), (BH, nq, d), jnp.float32)
    v = jax.random.normal(jax.random.key(seed + 2), (BH, nq, d), jnp.float32)
    out = flash_attention_pallas(q, k, v, window=window, bq=32, bk=32,
                                 interpret=True)
    expect = ref.flash_attention(q, k, v, window=window)
    _assert_close(out, expect, jnp.float32)


# ------------------------------------------------------------------ stencil
@pytest.mark.parametrize("m,n,bm", [(128, 128, 64), (256, 128, 128),
                                    (192, 256, 64)])
def test_stencil_shapes(m, n, bm):
    f = jax.random.normal(jax.random.key(0), (m, n), jnp.float32)
    out = stencil_pallas(f, bm=bm, interpret=True)
    _assert_close(out, ref.stencil(f), jnp.float32)


def test_stencil_matches_science_app_reference():
    from repro.science import stencil2d

    cfg = stencil2d.StencilConfig(nx=128, ny=128, steps=1)
    f = jax.random.normal(jax.random.key(1), (128, 128), jnp.float32)
    out = stencil_pallas(f, bm=64, interpret=True)
    expect = stencil2d.reference(f, cfg)
    _assert_close(out, expect, jnp.float32)


# --------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("t,n,bt", [(64, 16, 32), (128, 32, 64),
                                    (128, 64, 128)])
def test_wkv6_shapes(t, n, bt):
    BH = 3
    key = jax.random.key(0)
    r = jax.random.normal(key, (BH, t, n), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.key(1), (BH, t, n), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.key(2), (BH, t, n), jnp.float32) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.key(3), (BH, t, n))) * 0.5 + 0.4
    u = jax.random.normal(jax.random.key(4), (BH, n), jnp.float32) * 0.1
    y, s = wkv6_pallas(r, k, v, w, u, bt=bt, interpret=True)
    ye, se = ref.wkv6(r, k, v, w, u)
    _assert_close(y, ye, jnp.float32)
    _assert_close(s, se, jnp.float32)


def test_wkv6_chunking_invariance():
    """Same result regardless of time-chunk size (state carry correct)."""
    BH, t, n = 2, 128, 16
    key = jax.random.key(7)
    r = jax.random.normal(key, (BH, t, n), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.key(8), (BH, t, n), jnp.float32) * 0.3
    v = jax.random.normal(jax.random.key(9), (BH, t, n), jnp.float32) * 0.3
    w = jnp.full((BH, t, n), 0.9, jnp.float32)
    u = jnp.full((BH, n), 0.05, jnp.float32)
    y32, s32 = wkv6_pallas(r, k, v, w, u, bt=32, interpret=True)
    y128, s128 = wkv6_pallas(r, k, v, w, u, bt=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s128), rtol=1e-6)


def test_wkv6_matches_model_layer():
    """Kernel output == the model's scan implementation (zero init)."""
    from repro.models.rwkv6 import wkv6_scan

    B, S, H, N = 1, 48, 2, 16
    key = jax.random.key(3)
    r = jax.random.normal(key, (B, S, H, N), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.key(4), (B, S, H, N), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.key(5), (B, S, H, N), jnp.float32) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(jax.random.key(6), (B, S, H, N))) * 0.4 + 0.5
    u = jax.random.normal(jax.random.key(7), (H, N), jnp.float32) * 0.1
    state = jnp.zeros((B, H, N, N), jnp.float32)
    y_ref, s_ref_ = wkv6_scan(r, k, v, w, u, state)
    y, s = ops.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref_), rtol=2e-5,
                               atol=2e-5)


# ----------------------------------------------------------- segment rowmax
@pytest.mark.parametrize("rows,cols,seg", [(5, 512, 1), (8, 512, 8),
                                           (17, 96, 4), (3, 1024, 64),
                                           (1, 64, 64)])
def test_segment_rowmax_shapes(rows, cols, seg):
    from repro.kernels.segment_reduce import segment_rowmax_pallas

    vals = jnp.abs(jax.random.normal(jax.random.key(0), (rows, cols),
                                     jnp.float32))
    out = segment_rowmax_pallas(vals, seg, interpret=True)
    _assert_close(out, ref.segment_rowmax(vals, seg), jnp.float32)


@pytest.mark.parametrize("br,bc", [(4, 64), (8, 128), (16, 512)])
def test_segment_rowmax_block_sweep(br, bc):
    from repro.kernels.segment_reduce import segment_rowmax_pallas

    vals = jnp.abs(jax.random.normal(jax.random.key(5), (13, 256),
                                     jnp.float32))
    out = segment_rowmax_pallas(vals, 8, br=br, bc=bc, interpret=True)
    _assert_close(out, ref.segment_rowmax(vals, 8), jnp.float32)


def test_segment_rowmax_ops_wrapper():
    vals = jnp.abs(jax.random.normal(jax.random.key(6), (6, 192),
                                     jnp.float32))
    out = ops.segment_rowmax(vals, 4)
    _assert_close(out, ref.segment_rowmax(vals, 4), jnp.float32)


def test_segment_rowmax_seg_one_is_row_max():
    vals = jnp.abs(jax.random.normal(jax.random.key(7), (9, 300),
                                     jnp.float32))
    out = ops.segment_rowmax(vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals).max(axis=1),
                               rtol=1e-6)


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("t,di,n,bt", [(64, 16, 8, 32), (128, 24, 8, 64),
                                       (128, 32, 16, 128)])
def test_mamba_scan_shapes(t, di, n, bt):
    from repro.kernels.mamba_scan import mamba_scan_pallas

    B = 2
    key = jax.random.key(0)
    xs = jax.random.normal(key, (B, t, di), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (B, t, di))) * 0.2
    Bs = jax.random.normal(jax.random.key(2), (B, t, n), jnp.float32) * 0.5
    Cs = jax.random.normal(jax.random.key(3), (B, t, n), jnp.float32) * 0.5
    A = -jnp.exp(jax.random.normal(jax.random.key(4), (di, n)) * 0.3)
    y, s = mamba_scan_pallas(xs, dt, Bs, Cs, A, bt=bt, interpret=True)
    ye, se = ref.mamba_scan(xs, dt, Bs, Cs, A)
    _assert_close(y, ye, jnp.float32)
    _assert_close(s, se, jnp.float32)


def test_mamba_scan_matches_model_mixer():
    """Kernel == the hymba model's mamba recurrence (same discretization)."""
    from repro.configs import get_config
    from repro.models import build
    from repro.models.hymba import d_inner, mamba_mixer

    cfg = get_config("hymba-1.5b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    layer0 = jax.tree.map(lambda p: p[0], params["layers"])["mamba"]
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    out_model, state_model, _ = mamba_mixer(layer0, x, cfg)

    # Rebuild the kernel inputs exactly as the mixer does.
    di, n = d_inner(cfg), cfg.ssm_state
    xz = x @ layer0["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    from repro.models.hymba import _causal_conv

    xs, _ = _causal_conv(xs, layer0["conv"])
    xs = jax.nn.silu(xs)
    bc = xs @ layer0["w_bc"]
    B_ssm, C_ssm = jnp.split(bc, 2, axis=-1)
    dt_raw = (xs @ layer0["w_dt"]) @ layer0["w_dt_out"]
    dt = jax.nn.softplus(dt_raw + layer0["dt_bias"])
    A = -jnp.exp(layer0["A_log"])
    y, s = ops.mamba_scan(xs, dt, B_ssm, C_ssm, A)
    y = y + xs * layer0["D"]
    y = y * jax.nn.silu(z)
    out_kernel = y @ layer0["w_out"]
    # kernel multiplies (dt*x)*B, mixer (dt*B)*x — the fp32 reordering
    # amplifies through the 64-step exp-state recurrence (~0.5% worst rel).
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=1e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(state_model),
                               rtol=1e-2, atol=5e-2)
