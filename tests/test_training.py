"""Substrate tests: optimizer, data pipeline, checkpointing, resilience."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build
from repro.training import (
    AdamWConfig, TrainLoop, TrainState, init_state, make_train_step,
)
from repro.training import optimizer as opt_mod
from repro.data import make_pipeline
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.checkpoint import CheckpointManager
from repro.runtime import (
    FailureInjector, StragglerMonitor, Supervisor, compression, elastic_plan,
)


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_mod.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt_mod.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(opt_mod.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(opt_mod.cosine_lr(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=1e-3)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)


# --------------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    dc = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=7)
    pipe = SyntheticTokens(dc)
    a = pipe.batch_np(step=3)
    b = pipe.batch_np(step=3)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    # shards tile the global batch
    full = pipe.batch_np(step=5)["inputs"]
    s0 = pipe.batch_np(step=5, shard=0, n_shards=2)["inputs"]
    s1 = pipe.batch_np(step=5, shard=1, n_shards=2)["inputs"]
    assert s0.shape[0] == s1.shape[0] == 4
    assert not np.array_equal(s0, s1)
    # labels are next-token shifted inputs
    assert np.array_equal(a["inputs"][:, 1:], a["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 99))
def test_data_property_reproducible(step, seed):
    dc = DataConfig(vocab_size=64, seq_len=16, global_batch=2, seed=seed)
    p = SyntheticTokens(dc)
    np.testing.assert_array_equal(
        p.batch_np(step)["inputs"], p.batch_np(step)["inputs"]
    )


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
        for s in (10, 20, 30):
            mgr.save(s, tree, {"cursor": s})
        assert mgr.all_steps() == [20, 30]        # keep=2 GC'd step 10
        step, restored, extra = mgr.restore()
        assert step == 30 and extra["cursor"] == 30
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(restored["n"]["b"]),
                                      np.asarray(tree["n"]["b"]))


def test_checkpoint_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.ones(8)})
        import numpy as np_, pathlib, json
        path = pathlib.Path(d) / "step_1"
        # tamper with the payload
        z = dict(np_.load(path / "arrays.npz"))
        z["w"] = z["w"] + 1
        np_.savez(path / "arrays.npz", **z)
        with pytest.raises(IOError):
            mgr.restore(1)


def test_checkpoint_async_save():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True)
        mgr.save(5, {"w": jnp.ones(16)})
        mgr.wait()
        assert mgr.latest_step() == 5


# ----------------------------------------------------------- fault tolerance
def test_supervisor_restores_after_failure():
    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    state = init_state(model, jax.random.key(0), opt_cfg)
    pipe = make_pipeline(cfg, seq_len=16, global_batch=4)
    step_jit = jax.jit(make_train_step(model, opt_cfg))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)

        def step_fn(step, tree):
            st = TrainState.from_tree(tree)
            st, metrics = step_jit(st, pipe.batch(step))
            return st.as_tree(), {k: float(v) for k, v in metrics.items()}

        sup = Supervisor(mgr, max_restarts=2)
        injector = FailureInjector(fail_at_steps=(7,), max_failures=1)
        final, history = sup.run(
            state=state.as_tree(), start_step=0, n_steps=12,
            step_fn=step_fn, save_every=5, injector=injector,
        )
        events = [h for h in history if "event" in h]
        assert len(events) == 1 and "restored" in events[0]["event"]
        # Training completed all 12 steps despite the failure.
        steps_done = {h["step"] for h in history if "loss" in h}
        assert max(steps_done) == 11
        assert sup.restarts == 1


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(n_replicas=8, threshold=1.4)
    times = np.full(8, 1.0)
    for _ in range(5):
        report = mon.observe(times)
    assert report["stragglers"] == []
    times[3] = 2.5
    for _ in range(10):
        report = mon.observe(times)
    assert report["stragglers"] == [3]
    assert report["plan"]["action"] == "rebalance"


def test_elastic_plan_after_chip_loss():
    from repro.core.autosharder import LMWorkload

    wl = LMWorkload(
        global_batch=256, seq_len=4096, d_model=2048, n_layers=24,
        n_heads=32, n_kv_heads=8, param_count=2e9,
    )
    plan = elastic_plan(509, wl)          # lost 3 chips of 512
    # 509 is infeasible (prime; dp must divide the batch) — land on the
    # nearest feasible count that fits the survivors, not a blanket
    # power-of-two collapse.
    assert plan["usable_chips"] == 256
    assert plan["mesh"]["data"] * plan["mesh"]["model"] == 256


def test_elastic_plan_keeps_non_power_of_two_survivors():
    from repro.core.autosharder import LMWorkload

    wl = LMWorkload(
        global_batch=240, seq_len=4096, d_model=2048, n_layers=24,
        n_heads=32, n_kv_heads=8, param_count=2e9,
    )
    plan = elastic_plan(12, wl)           # lost 4 chips of 16
    # dp=12 divides the 240 batch: all 12 survivors stay in the mesh
    # (the old power-of-two shortcut collapsed this to 8).
    assert plan["usable_chips"] == 12
    assert plan["idle_chips"] == 0
    assert plan["mesh"]["data"] * plan["mesh"]["model"] == 12


# ---------------------------------------------------------------- compression
def test_int8_compression_error_feedback():
    key = jax.random.key(0)
    g = {"w": jax.random.normal(key, (1000,)) * 0.01}
    err = compression.init_error(g)
    comp, err2 = compression.compress_tree(g, err)
    # quantization error is bounded by the block scale
    delta = np.abs(np.asarray(comp["w"] - g["w"]))
    scale = float(np.abs(np.asarray(g["w"])).max() / 127.0)
    assert delta.max() <= scale * 1.01
    # error feedback: err2 holds exactly the residual
    np.testing.assert_allclose(
        np.asarray(comp["w"] + err2["w"]), np.asarray(g["w"]), rtol=1e-5,
        atol=1e-7,
    )


def test_compressed_training_still_converges():
    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    state = init_state(model, jax.random.key(0), opt_cfg, compress_grads=True)
    pipe = make_pipeline(cfg, seq_len=32, global_batch=8)
    step_fn = jax.jit(make_train_step(model, opt_cfg, compress_grads=True))
    loop = TrainLoop(step_fn, pipe, backpressure=1)
    state, hist = loop.run(state, 0, 25, log_every=0)
    assert hist[-1]["loss"] < hist[0]["loss"]
