import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Runs the three selected (arch x shape) cells through a sequence of named
knob configurations, recording the roofline terms of each step. The
narrative (hypothesis / predicted delta / confirmed-refuted) lives in
EXPERIMENTS.md §Perf; this driver produces the numbers.

  PYTHONPATH=src python benchmarks/perf_iterations.py \
      --out results/perf_iterations.json
"""
import argparse
import json
from pathlib import Path


def experiments():
    from repro.launch.knobs import Knobs

    base = dict(sp_attention=False, wkv_impl="scan", microbatch=1)
    return [
        # ---- cell 1: worst roofline fraction (memory term pathological)
        {
            "cell": ("rwkv6-3b", "train_4k", "single"),
            "steps": [
                ("baseline: per-step WKV scan", Knobs(**base)),
                ("chunked WKV (flash-linear-attention form)",
                 Knobs(**{**base, "wkv_impl": "chunked"})),
                ("chunked WKV + microbatch=2",
                 Knobs(**{**base, "wkv_impl": "chunked", "microbatch": 2})),
            ],
        },
        # ---- cell 2: most collective-bound (score-block resharding)
        {
            "cell": ("musicgen-medium", "train_4k", "single"),
            "steps": [
                ("baseline: partitioner-resharded attention", Knobs(**base)),
                ("bf16 params before gather (REFUTED: no change)",
                 Knobs(**{**base, "bf16_gather": True})),
                ("shard_map SP attention",
                 Knobs(**{**base, "sp_attention": True})),
                ("SP attention + microbatch=4",
                 Knobs(**{**base, "sp_attention": True, "microbatch": 4})),
            ],
        },
        # ---- cell 3: the paper's own technique (EP dispatch volume)
        {
            "cell": ("deepseek-v2-lite-16b", "train_4k", "single"),
            "steps": [
                ("baseline: capacity 1.25", Knobs(**base)),
                ("capacity 1.0 (a2a cut)",
                 Knobs(**{**base, "moe_capacity": 1.0})),
                ("+ shard_map SP attention",
                 Knobs(**{**base, "moe_capacity": 1.0,
                          "sp_attention": True})),
                ("+ microbatch=4 (policy)",
                 Knobs(**{**base, "moe_capacity": 1.0, "sp_attention": True,
                          "microbatch": 0})),
            ],
        },
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf_iterations.json")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    results = []
    for exp in experiments():
        arch, shape, mesh = exp["cell"]
        print(f"\n### {arch} x {shape} x {mesh}")
        for name, knobs in exp["steps"]:
            rec = run_cell(arch, shape, mesh, knobs=knobs, verbose=False)
            rt = rec.get("roofline", {})
            mem = rec.get("memory_analysis", {})
            row = {
                "cell": exp["cell"], "step": name,
                "status": rec["status"],
                "compute_s": rt.get("compute_s"),
                "memory_s": rt.get("memory_s"),
                "collective_s": rt.get("collective_s"),
                "bottleneck": rt.get("bottleneck"),
                "useful": rt.get("useful_flops_ratio"),
                "temp_gib": mem.get("temp_size_in_bytes", 0) / 2**30,
                "collective_bytes": rec.get("collective_bytes"),
                "error": rec.get("error"),
            }
            results.append(row)
            if rec["status"] == "ok":
                print(f"  {name:45s} comp={row['compute_s']:.3e} "
                      f"mem={row['memory_s']:.3e} "
                      f"coll={row['collective_s']:.3e} "
                      f"[{row['bottleneck']}] useful={row['useful']:.2f} "
                      f"temp={row['temp_gib']:.1f}GiB")
            else:
                print(f"  {name:45s} ERROR: {row['error']}")
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(results, indent=1))
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
