"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Runs the three selected (arch x shape) cells through a sequence of named
knob configurations, recording the roofline terms of each step. The
narrative (hypothesis / predicted delta / confirmed-refuted) lives in
EXPERIMENTS.md §Perf; this driver produces the numbers.

  PYTHONPATH=src python benchmarks/perf_iterations.py \
      --out results/perf_iterations.json

``run()`` (the ``python -m benchmarks.run`` section) summarizes a
previously recorded artifact — regenerating it relowers multi-billion
parameter models, so the aggregate runner reads, never recomputes.
"""
import argparse
import json
import os
from pathlib import Path

RESULTS_PATH = Path(__file__).resolve().parent.parent / "results" \
    / "perf_iterations.json"


def experiments():
    from repro.launch.knobs import Knobs

    base = dict(sp_attention=False, wkv_impl="scan", microbatch=1)
    return [
        # ---- cell 1: worst roofline fraction (memory term pathological)
        {
            "cell": ("rwkv6-3b", "train_4k", "single"),
            "steps": [
                ("baseline: per-step WKV scan", Knobs(**base)),
                ("chunked WKV (flash-linear-attention form)",
                 Knobs(**{**base, "wkv_impl": "chunked"})),
                ("chunked WKV + microbatch=2",
                 Knobs(**{**base, "wkv_impl": "chunked", "microbatch": 2})),
            ],
        },
        # ---- cell 2: most collective-bound (score-block resharding)
        {
            "cell": ("musicgen-medium", "train_4k", "single"),
            "steps": [
                ("baseline: partitioner-resharded attention", Knobs(**base)),
                ("bf16 params before gather (REFUTED: no change)",
                 Knobs(**{**base, "bf16_gather": True})),
                ("shard_map SP attention",
                 Knobs(**{**base, "sp_attention": True})),
                ("SP attention + microbatch=4",
                 Knobs(**{**base, "sp_attention": True, "microbatch": 4})),
            ],
        },
        # ---- cell 3: the paper's own technique (EP dispatch volume)
        {
            "cell": ("deepseek-v2-lite-16b", "train_4k", "single"),
            "steps": [
                ("baseline: capacity 1.25", Knobs(**base)),
                ("capacity 1.0 (a2a cut)",
                 Knobs(**{**base, "moe_capacity": 1.0})),
                ("+ shard_map SP attention",
                 Knobs(**{**base, "moe_capacity": 1.0,
                          "sp_attention": True})),
                ("+ microbatch=4 (policy)",
                 Knobs(**{**base, "moe_capacity": 1.0, "sp_attention": True,
                          "microbatch": 0})),
            ],
        },
    ]


def run(report=print, path: Path = RESULTS_PATH) -> dict:
    """Summarize the recorded hillclimb artifact (per-cell best step).

    Raises ``FileNotFoundError`` when the artifact is absent — the
    aggregate runner prints its standard skip line, matching the
    roofline report's behavior for missing dry-run artifacts.
    """
    rows = json.loads(Path(path).read_text())
    ok_rows = [r for r in rows if r["status"] == "ok"]
    report(f"{'cell':45s} {'step':45s} {'dominant_s':>11s} {'bound':>10s}")
    cells: dict[tuple, list] = {}
    for r in ok_rows:
        cells.setdefault(tuple(r["cell"]), []).append(r)
    improvements = []
    for cell, steps in cells.items():
        dom = [max(s["compute_s"], s["memory_s"], s["collective_s"])
               for s in steps]
        for s, d in zip(steps, dom):
            report(f"{'x'.join(cell):45s} {s['step'][:45]:45s} {d:11.3e} "
                   f"{s['bottleneck']:>10s}")
        if len(dom) > 1 and dom[-1] > 0:
            improvements.append(dom[0] / dom[-1])
    if improvements:
        report(f"\n{len(cells)} cells; baseline -> final dominant-term "
               f"speedups: "
               + ", ".join(f"{x:.2f}x" for x in improvements))
    return {"cells": len(cells), "rows": len(ok_rows),
            "speedups": improvements}


def main() -> None:
    # Must happen before JAX initializes; append to any existing XLA_FLAGS
    # rather than silently losing the fake-device count (or the flags).
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count=512"
        ).strip()
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(RESULTS_PATH))
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    results = []
    for exp in experiments():
        arch, shape, mesh = exp["cell"]
        print(f"\n### {arch} x {shape} x {mesh}")
        for name, knobs in exp["steps"]:
            rec = run_cell(arch, shape, mesh, knobs=knobs, verbose=False)
            rt = rec.get("roofline", {})
            mem = rec.get("memory_analysis", {})
            row = {
                "cell": exp["cell"], "step": name,
                "status": rec["status"],
                "compute_s": rt.get("compute_s"),
                "memory_s": rt.get("memory_s"),
                "collective_s": rt.get("collective_s"),
                "bottleneck": rt.get("bottleneck"),
                "useful": rt.get("useful_flops_ratio"),
                "temp_gib": mem.get("temp_size_in_bytes", 0) / 2**30,
                "collective_bytes": rec.get("collective_bytes"),
                "error": rec.get("error"),
            }
            results.append(row)
            if rec["status"] == "ok":
                print(f"  {name:45s} comp={row['compute_s']:.3e} "
                      f"mem={row['memory_s']:.3e} "
                      f"coll={row['collective_s']:.3e} "
                      f"[{row['bottleneck']}] useful={row['useful']:.2f} "
                      f"temp={row['temp_gib']:.1f}GiB")
            else:
                print(f"  {name:45s} ERROR: {row['error']}")
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(results, indent=1))
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
