"""Table 2 reproduction: mapper tuning headroom per application — by SEARCH.

Where this harness used to read a hand-coded (default, tuned) volume pair
per app, it now runs the mapper autotuner (``repro.search``): every app's
declared search space is enumerated, scored with its cost model,
beam-pruned and evaluated through the vectorized ``assignment_grid`` batch
path; the Table 2 speedups are computed from the *searched* optimum. The
legacy pair survives only as a regression oracle — the tuner must
rediscover the default volume exactly and achieve volume <= the hand-tuned
value — so the paper's speedups come out of search, bit-for-bit.

Run with ``PYTHONPATH=src``:

    PYTHONPATH=src python benchmarks/mapper_tuning.py --json BENCH_tuning.json

Writes ``BENCH_tuning.json`` (the CI perf artifact). Exits non-zero if any
oracle is missed, any winner fails DSL verification, any evaluation falls
off the vectorized path, or whole-registry tuning exceeds the 5 s budget.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro import apps
from repro.core.machine import modeled_step_time as model_time
from repro.search.tuner import tune_app

CHIPS = 64
TIME_BUDGET_S = 5.0          # acceptance: whole-registry tuning at 64 procs


def run(report=print, chips: int = CHIPS,
        json_path: str | None = "BENCH_tuning.json") -> dict:
    rows = []
    t0 = time.perf_counter()
    for app in apps.iter_apps():
        if app.search_space is None:
            continue
        rep = tune_app(app, chips)
        flops = app.step_flops(rep.procs)
        v_def = rep.default.volume if rep.default is not None else rep.best.volume
        t_def = model_time(flops, v_def, rep.procs)
        t_best = model_time(flops, rep.best.volume, rep.procs)
        speedup = t_def / t_best
        oracle_speedup = None
        if rep.oracle is not None:
            o_def, o_tuned = rep.oracle
            oracle_speedup = (
                model_time(flops, o_def, rep.procs)
                / model_time(flops, o_tuned, rep.procs)
            )
        rows.append({
            "app": app.name,
            "procs": rep.procs,
            "machine": list(rep.machine_shape),
            "volume_default": v_def,
            "volume_best": rep.best.volume,
            "best_candidate": rep.best.candidate.describe(),
            "best_ir": rep.best_ir,
            "candidates": rep.candidates_considered,
            "evaluated": rep.variants_evaluated,
            "pruned": rep.pruned,
            "speedup": speedup,
            "oracle": None if rep.oracle is None else list(rep.oracle),
            "oracle_speedup": oracle_speedup,
            "oracle_ok": rep.oracle_ok,
            # bit-for-bit Table 2: searched speedup equals the legacy pair's
            "speedup_matches_oracle": (
                oracle_speedup is None or speedup == oracle_speedup
            ),
            # a search-space improvement may legitimately BEAT the pair;
            # only falling short of it is a regression
            "speedup_below_oracle": (
                oracle_speedup is not None
                and speedup < oracle_speedup * (1 - 1e-9)
            ),
            "dsl_verified": rep.verified,
            "eval_path": rep.best.eval_path,
            "elapsed_s": rep.elapsed_s,
            "note": rep.note,
        })
    elapsed = time.perf_counter() - t0

    report(f"{'app':12s} {'procs':>5s} {'cands':>6s} {'eval':>5s} "
           f"{'best candidate':22s} {'tuned speedup':>14s} {'oracle':>7s}   "
           f"(paper Table 2: 1.02-1.34x)")
    for r in rows:
        report(f"{r['app']:12s} {r['procs']:5d} {r['candidates']:6d} "
               f"{r['evaluated']:5d} {r['best_candidate']:22s} "
               f"{r['speedup']:13.2f}x {str(r['oracle_ok']):>7s}")
    report(f"whole-registry search: {elapsed:.2f}s "
           f"(budget {TIME_BUDGET_S:.0f}s)")

    result = {
        "chips_requested": chips,
        "rows": rows,
        "elapsed_s": elapsed,
        "time_budget_s": TIME_BUDGET_S,
        "all_oracles_rediscovered": all(r["oracle_ok"] for r in rows),
        "all_speedups_match_oracle": all(
            r["speedup_matches_oracle"] for r in rows
        ),
        "any_speedup_below_oracle": any(
            r["speedup_below_oracle"] for r in rows
        ),
        "all_dsl_verified": all(r["dsl_verified"] for r in rows),
        "all_vectorized": all(r["eval_path"] == "vectorized" for r in rows),
        "within_budget": elapsed < TIME_BUDGET_S,
    }
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        report(f"wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chips", type=int, default=CHIPS)
    ap.add_argument("--json", default="BENCH_tuning.json",
                    help="output path for the machine-readable results")
    args = ap.parse_args(argv)

    result = run(chips=args.chips, json_path=args.json)
    ok = True
    if not result["all_oracles_rediscovered"]:
        print("ERROR: tuner failed to rediscover a hand-tuned volume",
              file=sys.stderr)
        ok = False
    if result["any_speedup_below_oracle"]:
        print("ERROR: searched speedup fell below the Table 2 pair",
              file=sys.stderr)
        ok = False
    elif not result["all_speedups_match_oracle"]:
        # Strictly better than the legacy pair: not a failure, but the
        # oracle should be updated to the new searched optimum.
        print("NOTE: search beat the legacy Table 2 pair; update the "
              "tuning oracle to the searched optimum")
    if not result["all_dsl_verified"]:
        print("ERROR: a winning mapper's rendered DSL diverged from its IR",
              file=sys.stderr)
        ok = False
    if not result["all_vectorized"]:
        print("ERROR: a candidate evaluation fell off the vectorized batch "
              "path", file=sys.stderr)
        ok = False
    if not result["within_budget"]:
        print(f"ERROR: registry tuning took {result['elapsed_s']:.2f}s "
              f"(budget {TIME_BUDGET_S:.0f}s)", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
