"""Table 2 reproduction: mapper tuning headroom per application.

For every app in the unified registry, compare its default mapper against
the best alternative Mapple expresses in a few lines — the paper's point is
that the DSL makes this search cheap. Each :class:`~repro.apps.Application`
carries the (default, tuned) communication-volume pair for the experiment
(``app.tuning``); the improvement metric is modeled step time on the v5e
fabric (compute + cross-fabric communication).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import apps  # noqa: E402
from repro.core import machine as hw  # noqa: E402

CHIPS = 64
BYTES = 4
LINK = hw.ICI_BW_PER_LINK * hw.ICI_LINKS_PER_CHIP


def model_time(flops_total: float, comm_elems: float, chips: int) -> float:
    compute = flops_total / (chips * hw.PEAK_FLOPS_BF16)
    comm = comm_elems * BYTES / (chips * LINK)
    return max(compute, comm) + 0.1 * min(compute, comm)


def run(report=print) -> dict:
    rows = []
    for app in apps.iter_apps():
        if app.tuning is None:
            continue
        chips = CHIPS
        try:
            v_def, v_tuned = app.tuning(chips)
        except ValueError:          # app cannot use CHIPS processors
            chips = app.default_procs
            v_def, v_tuned = app.tuning(chips)
        flops = app.step_flops(chips)
        t_def = model_time(flops, v_def, chips)
        t_tun = model_time(flops, v_tuned, chips)
        rows.append((app.name, t_def / t_tun))
    report(f"{'app':12s} {'tuned speedup':>14s}   (paper Table 2: 1.02-1.34x)")
    for name, sp in rows:
        report(f"{name:12s} {sp:13.2f}x")
    return {name: sp for name, sp in rows}


if __name__ == "__main__":
    run()
