"""Table 2 reproduction: mapper tuning headroom per application.

For each of the nine applications, compare the default mapper against the
best alternative Mapple expresses in a few lines — the paper's point is
that the DSL makes this search cheap. The improvement metric is modeled
step time on the v5e fabric (compute + cross-fabric communication), the
same model validated against the dry-run artifacts in EXPERIMENTS.md.
"""
from __future__ import annotations

import math

from repro.core import GPU, Machine
from repro.core import machine as hw
from repro.core.commvolume import (
    MatmulProblem,
    cannon_volume,
    cosma_grid,
    halo_surface_volume,
    johnson_volume,
    solomonik_volume,
    summa_volume,
)
from repro.core.decompose import greedy_factorization, optimal_factorization

CHIPS = 64
BYTES = 4
LINK = hw.ICI_BW_PER_LINK * hw.ICI_LINKS_PER_CHIP


def model_time(flops_total: float, comm_elems: float, chips: int) -> float:
    compute = flops_total / (chips * hw.PEAK_FLOPS_BF16)
    comm = comm_elems * BYTES / (chips * LINK)
    return max(compute, comm) + 0.1 * min(compute, comm)


def matmul_rows():
    p = MatmulProblem(16384, 16384, 16384)
    q = int(math.sqrt(CHIPS))
    rows = []
    # default vs tuned (per-algorithm tuning knob)
    cfgs = {
        "cannon": (cannon_volume(p, (q, q)), cannon_volume(p, (q, q))),
        "summa": (summa_volume(p, (q, q)),
                  summa_volume(p, (q, q), panel=4)),
        "pumma": (summa_volume(p, (q, q)), summa_volume(p, (q, q))),
        # johnson: default cube vs decompose-tuned grid
        "johnson": (johnson_volume(p, (4, 4, 4)),
                    johnson_volume(p, cosma_grid(p, CHIPS))),
        # solomonik: c=1 (2D) vs tuned replication c=4
        "solomonik": (solomonik_volume(p, (8, 8, 1)),
                      solomonik_volume(p, (4, 4, 4))),
        # cosma picks its own grid; baseline = balanced greedy grid
        "cosma": (johnson_volume(p, tuple(greedy_factorization(CHIPS, 3))),
                  johnson_volume(p, cosma_grid(p, CHIPS))),
    }
    for name, (v_def, v_tuned) in cfgs.items():
        t_def = model_time(p.flops, v_def, CHIPS)
        t_tun = model_time(p.flops, v_tuned, CHIPS)
        rows.append((name, t_def / t_tun))
    return rows


def science_rows():
    rows = []
    # stencil/pennant: greedy grid vs decompose grid on a 1:8 space
    for name, lengths in (("stencil", (4096, 32768)),
                          ("pennant", (2048, 16384))):
        v_def = halo_surface_volume(lengths, greedy_factorization(CHIPS, 2))
        v_tun = halo_surface_volume(
            lengths, optimal_factorization(CHIPS, lengths)
        )
        flops = 5.0 * lengths[0] * lengths[1] * 64  # 64 sweeps
        t_def = model_time(flops, v_def * 64, CHIPS)
        t_tun = model_time(flops, v_tun * 64, CHIPS)
        rows.append((name, t_def / t_tun))
    # circuit: memory-placement tuning (ZCMEM for the shared node charge
    # avoids a device round trip — modeled as removing one gather pass)
    wires, frac_external = 10_000_000, 0.1
    v_def = wires * (1 + frac_external) * 2     # gather V + scatter Q
    v_tun = wires * (1 + frac_external) * 2 * 0.75
    flops = wires * 12.0
    rows.append((
        "circuit",
        model_time(flops, v_def, CHIPS) / model_time(flops, v_tun, CHIPS),
    ))
    return rows


def run(report=print) -> dict:
    rows = matmul_rows() + science_rows()
    report(f"{'app':12s} {'tuned speedup':>14s}   (paper Table 2: 1.02-1.34x)")
    for name, sp in rows:
        report(f"{name:12s} {sp:13.2f}x")
    return {name: sp for name, sp in rows}


if __name__ == "__main__":
    run()
