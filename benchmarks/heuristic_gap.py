"""Heuristic gap: greedy baseline vs. the autotuner's optimum.

The paper's Algorithm 1 (the Chapel-style heuristic) is iteration-space
*oblivious*; Sec. 4 proves it suboptimal and the evaluation measures the
gap. This harness quantifies it by SEARCH: for every registry app with a
declared search space, the greedy factorization of the processor count is
scored with the app's own cost model and compared against the mapper the
autotuner finds (``repro.search``), across a processor sweep. The headline
is the largest margin — the paper reports the tuned mapper beating the
heuristic by up to 1.83x.

The Fig. 13 mechanism study is kept as :func:`cannon_locality`: with the
algorithm-specified mapping Cannon's ring neighbours are fabric
neighbours; a runtime round-robin heuristic turns shifts into cross-node
traffic and serializes them onto one hot link.

Run with ``PYTHONPATH=src``:

    PYTHONPATH=src python benchmarks/heuristic_gap.py
"""
from __future__ import annotations

import sys

import numpy as np

from repro import apps
from repro.core import GPU, Machine
from repro.core.commvolume import MatmulProblem, cannon_volume
from repro.core.decompose import greedy_factorization
from repro.core.machine import modeled_step_time as _model_time
from repro.matmul import runtime_heuristic_mapper
from repro.search.tuner import tune_app

PROC_SWEEP = (4, 16, 64, 128)
PAPER_MARGIN = 1.83          # paper: tuner beats the heuristic by up to 1.83x


# ------------------------------------------------- greedy vs tuner optimum
def greedy_vs_tuner(report=print) -> dict:
    rows = []
    for app in apps.iter_apps():
        space = app.search_space
        if space is None:
            continue
        for procs in PROC_SWEEP:
            if not space.grids(procs):
                continue            # app cannot use this processor count
            greedy = tuple(greedy_factorization(procs, space.rank))
            if space.grid_ok is not None and not space.grid_ok(greedy):
                continue            # heuristic's grid is not even valid
            rep = tune_app(app, procs)
            if rep.procs != procs:
                continue            # tuner fell back to another scale
            # Score greedy under the tuner winner's option choices so the
            # margin isolates the factorization axis (Algorithm 1's actual
            # blind spot), not option-axis wins like memory placement.
            model = space.cost_model(procs, rep.best.candidate.opts)
            try:
                v_greedy = float(model.cost(greedy))
            except ValueError:
                continue
            margin = v_greedy / max(rep.best.volume, 1e-12)
            flops = app.step_flops(procs)
            t_margin = (
                _model_time(flops, v_greedy, procs)
                / _model_time(flops, rep.best.volume, procs)
            )
            rows.append({
                "app": app.name,
                "procs": procs,
                "greedy_grid": list(greedy),
                "v_greedy": v_greedy,
                "best_candidate": rep.best.candidate.describe(),
                "v_tuner": rep.best.volume,
                "volume_margin": margin,
                "time_margin": t_margin,
            })
    report(f"{'app':12s} {'procs':>5s} {'greedy grid':>12s} "
           f"{'tuner best':>22s} {'vol margin':>10s} {'time margin':>11s}")
    for r in rows:
        gg = "x".join(str(g) for g in r["greedy_grid"])
        report(f"{r['app']:12s} {r['procs']:5d} {gg:>12s} "
               f"{r['best_candidate']:>22s} {r['volume_margin']:9.2f}x "
               f"{r['time_margin']:10.2f}x")
    max_margin = max((r["time_margin"] for r in rows), default=0.0)
    report(f"max tuner-over-greedy margin: {max_margin:.2f}x "
           f"(paper: up to {PAPER_MARGIN:.2f}x)")
    return {"rows": rows, "max_margin": max_margin,
            "paper_margin": PAPER_MARGIN}


# --------------------------------------------------- Fig. 13 locality study
def cross_node_fraction(perm: np.ndarray, grid: tuple[int, int],
                        gpus_per_node: int) -> float:
    """Fraction of Cannon shift hops that cross a node boundary."""
    q1, q2 = grid
    dev = perm.reshape(grid)
    node = dev // gpus_per_node
    cross = total = 0
    for i in range(q1):
        for j in range(q2):
            # one shift left (A) and one shift up (B) per step
            for ni, nj in ((i, (j + 1) % q2), ((i + 1) % q1, j)):
                total += 1
                cross += int(node[i, j] != node[ni, nj])
    return cross / total


def max_link_load(perm: np.ndarray, grid: tuple[int, int],
                  gpus_per_node: int) -> int:
    """Hot inter-node link: max tiles moved over one directed node pair in
    one Cannon step. The heuristic's linearized placement serializes every
    row shift onto the same node pair — this is the mechanism behind the
    paper's Fig. 13 slowdowns (shift time ~ hot-link load)."""
    q1, q2 = grid
    dev = perm.reshape(grid)
    node = dev // gpus_per_node
    loads: dict = {}
    for i in range(q1):
        for j in range(q2):
            for ni, nj in ((i, (j + 1) % q2), ((i + 1) % q1, j)):
                a, b = int(node[i, j]), int(node[ni, nj])
                if a != b:
                    loads[(b, a)] = loads.get((b, a), 0) + 1
    return max(loads.values()) if loads else 0


def cannon_locality(report=print) -> dict:
    app = apps.get("cannon")
    rows = []
    for n in (4, 16, 64):
        nodes, gpn = app.machine_shape(n)
        grid = app.tile_grid(n)
        machine = Machine(GPU, shape=(nodes, gpn))
        spec = app.mapper(n).tile_permutation(grid, n)
        heur = runtime_heuristic_mapper(machine).tile_permutation(grid, n)
        f_spec = cross_node_fraction(spec, grid, gpn)
        f_heur = cross_node_fraction(heur, grid, gpn)
        l_spec = max_link_load(spec, grid, gpn)
        l_heur = max_link_load(heur, grid, gpn)
        p = MatmulProblem(8192, 8192, 8192)
        vol = cannon_volume(p, grid)
        # shift time ~ hot-link load x tile bytes / link bw
        rows.append({
            "machine": f"{nodes}x{gpn}", "grid": f"{grid[0]}x{grid[1]}",
            "cross_frac_spec": f_spec, "cross_frac_heur": f_heur,
            "hotlink_spec": l_spec, "hotlink_heur": l_heur,
            "cross_bytes_spec": vol * f_spec * 4,
            "cross_bytes_heur": vol * f_heur * 4,
            "shift_slowdown": l_heur / max(l_spec, 1),
        })
    report(f"{'machine':8s} {'grid':6s} {'xnode(spec)':>12s} "
           f"{'xnode(heur)':>12s} {'hotlink s/h':>12s} {'slowdown':>9s}")
    for r in rows:
        report(f"{r['machine']:8s} {r['grid']:6s} "
               f"{r['cross_frac_spec']:12.2f} {r['cross_frac_heur']:12.2f} "
               f"{r['hotlink_spec']:5d}/{r['hotlink_heur']:<6d} "
               f"{r['shift_slowdown']:8.2f}x")
    report("(paper Fig. 13: up to 3.5x slowdown + OOM from heuristic "
           "placement; slowdown here = hot inter-node link load ratio)")
    return {"rows": rows}


def run(report=print) -> dict:
    gap = greedy_vs_tuner(report)
    report("")
    fig13 = cannon_locality(report)
    return {"greedy_vs_tuner": gap, "fig13": fig13}


def main() -> int:
    result = run()
    if result["greedy_vs_tuner"]["max_margin"] < PAPER_MARGIN:
        print(f"ERROR: max tuner margin "
              f"{result['greedy_vs_tuner']['max_margin']:.2f}x below the "
              f"paper's {PAPER_MARGIN:.2f}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
