"""Fig. 13 reproduction: algorithm-specified mapping vs runtime heuristics.

The paper shows Cannon/PUMMA/SUMMA run up to 3.5x slower (and OOM at 32
GPUs) when the runtime round-robins tiles over GPUs instead of honoring the
algorithm's distribution. We reproduce the mechanism analytically — the
quantity that caused it — plus a small-scale wall-clock check on 8 fake
devices (subprocess, so this process keeps 1 device):

  * shift volume: with the specified mapping, Cannon's ring neighbours are
    ICI/NVLink neighbours; the heuristic permutation turns a fraction of
    the shifts into cross-node traffic;
  * peak memory: heuristic placement materializes remote panels per step
    (the paper's OOM at 32 GPUs).

The specified mapping comes from the unified app registry — the SAME parsed
Mapple program the end-to-end runner uses — not from a parallel code path.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import apps  # noqa: E402
from repro.core import GPU, Machine  # noqa: E402
from repro.core.commvolume import MatmulProblem, cannon_volume  # noqa: E402
from repro.matmul import runtime_heuristic_mapper  # noqa: E402

REPO = Path(__file__).resolve().parent.parent
PROC_SWEEP = (4, 16, 64)        # square counts; the paper sweeps 8..32 GPUs


def cross_node_fraction(perm: np.ndarray, grid: tuple[int, int],
                        gpus_per_node: int) -> float:
    """Fraction of Cannon shift hops that cross a node boundary."""
    q1, q2 = grid
    dev = perm.reshape(grid)
    node = dev // gpus_per_node
    cross = total = 0
    for i in range(q1):
        for j in range(q2):
            # one shift left (A) and one shift up (B) per step
            for ni, nj in ((i, (j + 1) % q2), ((i + 1) % q1, j)):
                total += 1
                cross += int(node[i, j] != node[ni, nj])
    return cross / total


def max_link_load(perm: np.ndarray, grid: tuple[int, int],
                  gpus_per_node: int) -> int:
    """Hot inter-node link: max tiles moved over one directed node pair in
    one Cannon step. The heuristic's linearized placement serializes every
    row shift onto the same node pair — this is the mechanism behind the
    paper's Fig. 13 slowdowns (shift time ~ hot-link load)."""
    q1, q2 = grid
    dev = perm.reshape(grid)
    node = dev // gpus_per_node
    loads: dict = {}
    for i in range(q1):
        for j in range(q2):
            for ni, nj in ((i, (j + 1) % q2), ((i + 1) % q1, j)):
                a, b = int(node[i, j]), int(node[ni, nj])
                if a != b:
                    loads[(b, a)] = loads.get((b, a), 0) + 1
    return max(loads.values()) if loads else 0


def analytic(report=print) -> dict:
    app = apps.get("cannon")
    rows = []
    for n in PROC_SWEEP:
        nodes, gpn = app.machine_shape(n)
        grid = app.tile_grid(n)
        machine = Machine(GPU, shape=(nodes, gpn))
        spec = app.mapper(n).tile_permutation(grid, n)
        heur = runtime_heuristic_mapper(machine).tile_permutation(grid, n)
        f_spec = cross_node_fraction(spec, grid, gpn)
        f_heur = cross_node_fraction(heur, grid, gpn)
        l_spec = max_link_load(spec, grid, gpn)
        l_heur = max_link_load(heur, grid, gpn)
        p = MatmulProblem(8192, 8192, 8192)
        vol = cannon_volume(p, grid)
        # shift time ~ hot-link load x tile bytes / link bw
        rows.append({
            "machine": f"{nodes}x{gpn}", "grid": f"{grid[0]}x{grid[1]}",
            "cross_frac_spec": f_spec, "cross_frac_heur": f_heur,
            "hotlink_spec": l_spec, "hotlink_heur": l_heur,
            "cross_bytes_spec": vol * f_spec * 4,
            "cross_bytes_heur": vol * f_heur * 4,
            "shift_slowdown": l_heur / max(l_spec, 1),
        })
    report(f"{'machine':8s} {'grid':6s} {'xnode(spec)':>12s} "
           f"{'xnode(heur)':>12s} {'hotlink s/h':>12s} {'slowdown':>9s}")
    for r in rows:
        report(f"{r['machine']:8s} {r['grid']:6s} "
               f"{r['cross_frac_spec']:12.2f} {r['cross_frac_heur']:12.2f} "
               f"{r['hotlink_spec']:5d}/{r['hotlink_heur']:<6d} "
               f"{r['shift_slowdown']:8.2f}x")
    report("(paper Fig. 13: up to 3.5x slowdown + OOM from heuristic "
           "placement; slowdown here = hot inter-node link load ratio)")
    return {"rows": rows}


WALLCLOCK_SNIPPET = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro import apps
from repro.core import Machine, GPU
from repro.matmul import cannon, runtime_heuristic_mapper
from repro.matmul.common import MatmulGrid, build_grid, make_inputs

app = apps.get("cannon")
m = Machine(GPU, shape=app.machine_shape(4))
a, b = make_inputs(512, 512, 512, seed=0)
plan = app.spmd_plan(4, devices=jax.devices()[:4])
for name, grid in [
    ("spec", MatmulGrid(mesh=plan.mesh, axis_names=plan.axis_names)),
    ("heur", build_grid(runtime_heuristic_mapper(m), (2, 2), ("x", "y"),
                        jax.devices()[:4])),
]:
    out = cannon.matmul(a, b, grid); jax.block_until_ready(out)  # warmup
    t0 = time.perf_counter()
    for _ in range(5):
        out = cannon.matmul(a, b, grid)
    jax.block_until_ready(out)
    print(f"{name},{(time.perf_counter() - t0) / 5 * 1e6:.0f}")
"""


def wallclock(report=print) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", WALLCLOCK_SNIPPET],
        capture_output=True, text=True, timeout=300, env=env,
    )
    out = {}
    if proc.returncode == 0:
        for line in proc.stdout.strip().splitlines():
            name, us = line.split(",")
            out[name] = float(us)
        report(f"cannon 512^3 on 4 fake devices: spec {out.get('spec', 0):.0f}us"
               f" vs heur {out.get('heur', 0):.0f}us (CPU emulation — device"
               f" permutation has no fabric cost here; the analytic table is"
               f" the hardware-relevant signal)")
    else:
        report(f"wallclock subprocess failed: {proc.stderr[-200:]}")
    return out


def run(report=print) -> dict:
    a = analytic(report)
    w = wallclock(report)
    return {"analytic": a, "wallclock": w}


if __name__ == "__main__":
    run()
