"""Low-level (no-DSL) mapper for cosma — LoC-baseline fixture.

The hand-written raw-JAX equivalent of the Mapple program registered
for this app in repro.apps.definitions. Not imported by production
code: benchmarks/loc_table.py counts its lines (Table 1) and checks
its assignment_grid against the DSL mapper's; everything else goes
through the registry pipeline.
"""
import itertools

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def balanced_3_factorization(n):
    """hand-rolled near-equal 3-way factorization (decompose replacement)"""
    best = (n, 1, 1)
    for a in range(1, n + 1):
        if n % a:
            continue
        for b in range(1, n // a + 1):
            if (n // a) % b:
                continue
            c = n // a // b
            cand = tuple(sorted((a, b, c), reverse=True))
            if max(cand) - min(cand) < max(best) - min(best):
                best = cand
    return best

def assign_point(point, space, machine_shape):
    nodes, gpus = machine_shape
    f = balanced_3_factorization(nodes * gpus)
    gx, gy = f[2], f[1]
    linearized = point[0] + point[1] * gx + point[2] * gx * gy
    return linearized % nodes, 0


MACHINE_SHAPE = (8, 1)
GRID_SHAPE = (2, 2, 2)
AXIS_NAMES = ("x", "y", "z")
MEMORY_KINDS = {"arg0": "device"}
DONATED_ARGS = ()
MAX_IN_FLIGHT = 2


def flat_device_id(node_idx, gpu_idx):
    return node_idx * MACHINE_SHAPE[1] + gpu_idx


def assignment_grid(grid_shape, machine_shape):
    out = np.empty(grid_shape, dtype=np.int64)
    for pt in itertools.product(*(range(s) for s in grid_shape)):
        out[pt] = flat_device_id(*assign_point(pt, grid_shape, machine_shape))
    return out


def validate_bijection(grid):
    flat = grid.reshape(-1)
    n = int(np.prod(MACHINE_SHAPE))
    if flat.size != n or len(np.unique(flat)) != n:
        raise ValueError(
            f"mapper is not a bijection onto {n} devices: {flat.tolist()}"
        )
    return flat


def build_mesh(devices=None):
    if devices is None:
        devices = jax.devices()
    grid = assignment_grid(GRID_SHAPE, MACHINE_SHAPE)
    perm = validate_bijection(grid)
    dev = np.asarray(devices, dtype=object)[perm].reshape(GRID_SHAPE)
    return Mesh(dev, AXIS_NAMES)


def operand_sharding(mesh, operand, spec_axes):
    kind = MEMORY_KINDS.get(operand, "device")
    try:
        return NamedSharding(mesh, P(*spec_axes), memory_kind=kind)
    except (TypeError, ValueError):
        return NamedSharding(mesh, P(*spec_axes))


def donate_argnums(arg_order):
    return tuple(i for i, a in enumerate(arg_order) if a in DONATED_ARGS)


class BoundedDispatcher:
    """Backpressure: cap the number of in-flight step results."""

    def __init__(self, depth=MAX_IN_FLIGHT):
        self.depth = depth
        self.pending = []

    def submit(self, fut):
        self.pending.append(fut)
        while len(self.pending) > self.depth:
            jax.block_until_ready(self.pending.pop(0))
