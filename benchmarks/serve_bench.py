"""Tuning-service load benchmark -> ``BENCH_serve.json``.

Two lanes, both gated (the committed floors fail CI on regression):

**replay** — a seeded request trace (mixed apps and scales, skewed
toward repeats, the regime a mapping service actually sees) replayed
through a :class:`~repro.serving.mapsvc.MappingService` twice over one
persistent ``--cache-dir``:

* *cold*: fresh directory — every unique question searches, repeats
  within the run coalesce or hit the warming plan cache;
* *warm*: a brand-new service instance over the same directory with
  every in-process cache cleared first — only the on-disk plan store
  carries over, and it must answer **every** request (hits ==
  requests, searches == 0, zero recomputation) with plans identical to
  the cold run's, at >= ``SERVE_WARM_FLOOR`` x the cold throughput.

**warm_start** — the search-quality side of warm starting, per registry
app: seeding ``tune_app`` with the cold winner must reproduce the cold
leaderboard bit-for-bit (the seed is already shortlisted -> superset
degenerates to equality), and cross-scale seeds (paper-scale winner
refit to 4x scale) must never rank worse than the cold search at that
scale.

Both lanes run on the NumPy pricing engine: determinism is the point
here, engine speed has its own lanes in ``sim_eval``.

    PYTHONPATH=src python benchmarks/serve_bench.py
    PYTHONPATH=src python benchmarks/serve_bench.py --requests 64
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import apps                                    # noqa: E402
from repro.search.tuner import refit_candidate, tune_app  # noqa: E402
from repro.serving.mapsvc import (                        # noqa: E402
    MappingService,
    Rejected,
    replay,
)
from repro.serving.serve import demo_trace                # noqa: E402
from repro.sim.collectives import clear_caches            # noqa: E402
from repro.sim.cost import time_tuned_app                 # noqa: E402

#: Acceptance: the warm (all-plan-cache-hits) replay must beat the cold
#: replay's throughput by at least this factor. Measured ~40-200x on CI
#: hardware; 3x leaves room for tiny traces and noisy runners.
SERVE_WARM_FLOOR = 3.0

DEFAULT_REQUESTS = 32
WARM_START_SCALE = 4     # cross-scale lane: seed paper scale -> 4x scale


def _plan_essence(res) -> dict | None:
    """The provenance-independent content of one resolved request."""
    if isinstance(res, Rejected):
        return None
    return {"app": res.app, "procs": res.procs,
            "candidate": res.candidate, "placed_cost": res.placed_cost,
            "source": res.source, "leaderboard": res.leaderboard}


def replay_bench(report=print, n_requests: int = DEFAULT_REQUESTS,
                 seed: int = 0) -> dict:
    """Cold vs warm trace replay through one plan-cache directory."""
    trace = demo_trace(n_requests, seed)
    root = Path(tempfile.mkdtemp(prefix="serve-bench-"))
    try:
        clear_caches()
        t0 = time.perf_counter()
        with MappingService(root, workers=0) as svc:
            cold_results = replay(svc, trace)
            cold_stats = svc.stats.summary()
        t_cold = time.perf_counter() - t0

        clear_caches()   # drop every in-process cache; disk carries over
        t0 = time.perf_counter()
        with MappingService(root, workers=0) as svc:
            warm_results = replay(svc, trace)
            warm_stats = svc.stats.summary()
        t_warm = time.perf_counter() - t0
    finally:
        shutil.rmtree(root, ignore_errors=True)

    plans_match = all(
        _plan_essence(c) == _plan_essence(w)
        for c, w in zip(cold_results, warm_results)
    )
    speedup = t_cold / t_warm if t_warm > 0 else float("inf")
    ok = (speedup >= SERVE_WARM_FLOOR
          and warm_stats["cache_hits"] == n_requests
          and warm_stats["searches"] == 0
          and cold_stats["completed"] == n_requests
          and warm_stats["completed"] == n_requests
          and plans_match)
    report(f"\nservice replay ({n_requests} requests): cold {t_cold:.2f}s "
           f"({cold_stats['searches']} searches, "
           f"{cold_stats['cache_hits']} hits, "
           f"{cold_stats['coalesced']} coalesced)  warm {t_warm:.3f}s "
           f"({warm_stats['cache_hits']} hits, "
           f"{warm_stats['searches']} searches)  speedup {speedup:.1f}x "
           f"(floor {SERVE_WARM_FLOOR:.0f}x)  plans match: {plans_match} "
           f"({'OK' if ok else 'FAIL'})")
    return {
        "requests": n_requests,
        "cold_s": t_cold,
        "warm_s": t_warm,
        "speedup": speedup,
        "speedup_floor": SERVE_WARM_FLOOR,
        "cold": cold_stats,
        "warm": warm_stats,
        "cold_p50_s": cold_stats["latency"]["p50_s"],
        "cold_p99_s": cold_stats["latency"]["p99_s"],
        "warm_p50_s": warm_stats["latency"]["p50_s"],
        "warm_p99_s": warm_stats["latency"]["p99_s"],
        "plans_match": plans_match,
        "ok": ok,
    }


def warm_start_bench(report=print) -> dict:
    """Warm-started search vs cold search across the registry."""
    rows = []
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        tuned = time_tuned_app(app)
        cold = tune_app(tuned)
        # Lane 1: seed with the cold winner — already shortlisted, so
        # the warm report must be bit-identical (warm_seeds == 0).
        warm = tune_app(tuned, warm_start=[cold.best.candidate])
        identical = (
            warm.warm_seeds == 0
            and [s.placed_cost for s in warm.leaderboard]
            == [s.placed_cost for s in cold.leaderboard]
            and warm.best.candidate == cold.best.candidate
        )
        # Lane 2: cross-scale — paper winner refit to 4x procs seeds
        # that scale's search; a superset beam can never rank worse.
        procs4 = cold.procs * WARM_START_SCALE
        not_worse = True
        seeded = 0
        if tuned.search_space.grids(procs4):
            cold4 = tune_app(tuned, procs4)
            seed = refit_candidate(tuned.search_space, cold.best.candidate,
                                   procs4)
            warm4 = tune_app(tuned, procs4,
                             warm_start=[seed] if seed else [])
            seeded = warm4.warm_seeds
            not_worse = warm4.best.rank_cost <= cold4.best.rank_cost
        rows.append({"app": app.name, "procs": cold.procs,
                     "identical_when_seed_known": identical,
                     "cross_scale_procs": procs4,
                     "cross_scale_seeds": seeded,
                     "cross_scale_not_worse": not_worse})
    ok = all(r["identical_when_seed_known"] and r["cross_scale_not_worse"]
             for r in rows)
    report(f"\nwarm-start search ({len(rows)} apps): self-seed bit-equal: "
           f"{all(r['identical_when_seed_known'] for r in rows)}, "
           f"cross-scale never worse: "
           f"{all(r['cross_scale_not_worse'] for r in rows)} "
           f"({'OK' if ok else 'FAIL'})")
    return {"apps": rows, "ok": ok}


def run(report=print, n_requests: int = DEFAULT_REQUESTS,
        json_path: str | None = "BENCH_serve.json") -> dict:
    result = {
        "replay": replay_bench(report, n_requests),
        "warm_start": warm_start_bench(report),
    }
    result["ok"] = result["replay"]["ok"] and result["warm_start"]["ok"]
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        report(f"wrote {json_path}")
    return result


def check(result: dict) -> list[str]:
    """Acceptance gates over a run's (or a loaded BENCH_serve.json's)
    result — shared by main() and the CI perf-regression lane."""
    errors = []
    rp = result.get("replay")
    if rp is not None:
        if rp["speedup"] < rp["speedup_floor"]:
            errors.append(
                f"warm service replay speedup {rp['speedup']:.1f}x fell "
                f"below the committed {rp['speedup_floor']:.0f}x floor")
        if rp["warm"]["cache_hits"] != rp["requests"] \
                or rp["warm"]["searches"] != 0:
            errors.append(
                "the warm replay recomputed instead of serving every "
                "request from the persistent plan cache")
        if not rp["plans_match"]:
            errors.append("warm-replay plans diverged from the cold run's")
    ws = result.get("warm_start")
    if ws is not None and not ws["ok"]:
        for r in ws["apps"]:
            if not r["identical_when_seed_known"]:
                errors.append(f"{r['app']}: seeding the known winner "
                              f"changed the report (must be bit-identical)")
            if not r["cross_scale_not_worse"]:
                errors.append(f"{r['app']}: a cross-scale warm start "
                              f"ranked worse than the cold search")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--json", default="BENCH_serve.json", metavar="PATH")
    args = ap.parse_args(argv)
    result = run(n_requests=args.requests, json_path=args.json)
    errors = check(result)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
