"""Simulator evaluation: time-domain tuning, engine parity, and scale.

Eight lanes, all recorded in ``BENCH_sim.json`` (the CI artifact next
to ``BENCH_mapping.json`` and ``BENCH_tuning.json``):

**Tuning oracle sweep** — for every registry application the mapper
autotuner runs TWICE, once with the analytic volume objective (the PR-3
search) and once with the simulator as the objective
(``repro.sim.cost.time_tuned_app``, same tuner, cost in predicted
seconds), and enforces:

  * **paper scale**: the simulated-time winner's communication volume
    matches the Table 2 tuning oracle (<= the hand-tuned volume);
  * **benchmark scale** (``--chips``, default 64): the time winner never
    regresses the oracle's *default* (untuned) volume. Halo apps may
    legitimately diverge from the *tuned* volume here (equally-NIC-loaded
    placements tie under max-port pricing; see docs/simulator.md);
  * **ranking agreement** >= 0.5 registry-wide, and a 10 s sweep budget.

**Engine parity** — the batched analytic-envelope engine
(``repro.sim.batch``) must agree with the exact event engine
(``simulate_steps(...).per_step_time()``) to 1e-9 on the paper cluster
for all nine apps, across default placements and every tuner variant.

**Engine speedup** — the 64-chip registry sweep: every feasible
(grid, options) point's default placement plus all its tuner variants,
priced by the batched engine in one grouped ``candidates x phases x
ports`` pass vs the event engine replaying each candidate. The measured
speedup must stay above the committed ``SPEEDUP_FLOOR`` (the CI
perf-regression lane re-checks the recorded value).

**JAX parity** — the device-compiled engine
(``repro.sim.jax_backend``, ``engine="batched-jax"``) must agree with
the NumPy engine to ``JAX_PARITY_RTOL`` (1e-6) relative on the paper
cluster, for all nine apps, every tuner variant, against NumPy pricing
with symmetry folding + incremental re-pricing both ON and OFF.

**JAX speedup** — the 4096-proc beam-pricing sweep: each feasible app's
most balanced grid, 8 seeded uniform-random-permutation placements (the
arbitrary-placement search workload, where the NumPy engine's fold and
incremental shortcuts structurally cannot fire), NumPy vs JAX, warm
caches/compiles, best of ``JAX_SWEEP_REPS``. The aggregate speedup must
stay above the committed ``JAX_SPEEDUP_FLOOR`` (2x; measured ~4x on
CPU jit).

**Pipeline** — the streaming Phase 3 (``repro.search.pipeline``) vs the
synchronous barrier on the 4096-proc random-placement sweep: per beam
group, real host expansion work (canonicalization + digesting of random
permutations) overlapped against device pricing. The CI box exposes a
single core, so the XLA-on-CPU "device" and the producer thread
time-slice and genuine overlap cannot appear in wall-clock; the lane
replays the JAX engine's real (precomputed, bit-exact) step times
behind a serial-occupancy device model whose busy window equals the
measured per-group expansion cost — the accelerator regime the
pipeline targets, where ``result()`` is a wait, not host compute. A
pipeline that stops overlapping (serializing dispatch-to-result)
regresses to ~1.0x and fails the committed ``PIPELINE_SPEEDUP_FLOOR``.

**Cache** — cold vs warm time-domain tuning of the full registry at
``CACHE_BENCH_PROCS`` procs through one persistent
:class:`repro.sim.price_cache.PriceCache` directory: the warm re-tune
must serve every placement from the cache (hits > 0, writes == 0),
reproduce the cold leaderboards exactly, and beat the committed
``CACHE_SPEEDUP_FLOOR``.

**Scale** — ``time_tuned_app`` must complete the full nine-app registry
at ``--scale-procs`` (default 1024) processors inside ``SCALE_BUDGET_S``.

**Scale suite** (``--scale``) — the 100k-proc lane, merged into an
existing ``BENCH_sim.json`` when one is present:

  * **fold parity**: symmetry-folded + incremental pricing must be
    *bit-equal* to dense pricing for every candidate placement of the
    probe apps at ``FOLD_PARITY_PROCS``, and the fold must actually
    fire (``FOLD_STATS['pairs_folded'] > 0``);
  * **registry at 16384**: the full nine-app registry time-tunes at
    ``SCALE_REGISTRY_PROCS`` inside ``SCALE_BUDGET_S``;
  * **XL**: one app (``stencil`` — 131072 has no square grid, so the
    systolic apps drop out) time-tunes at ``SCALE_XL_PROCS`` inside
    ``SCALE_BUDGET_S``.

``--quick`` runs the paper-scale tuning sweep + engine parity only (the
CI sim-smoke lane).

    PYTHONPATH=src python benchmarks/sim_eval.py --json BENCH_sim.json
    PYTHONPATH=src python benchmarks/sim_eval.py --scale --json BENCH_sim.json
"""
from __future__ import annotations

import argparse
import itertools
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro import apps
from repro.search.pipeline import PriceJob, price_job, stream_priced
from repro.search.space import build_program
from repro.search.tuner import tune_app
from repro.sim.batch import canonical_assignment, fold_stats, price_stacks
from repro.sim.collectives import clear_caches
from repro.sim.cost import time_search_space, time_tuned_app
from repro.sim.price_cache import PriceCache, digest

CHIPS = 64
TIME_BUDGET_S = 10.0     # acceptance: tuning-sweep budget (both scales)
MIN_AGREEMENT = 0.5
ENGINE_ATOL = 1e-9       # acceptance: batched-vs-event per-step agreement
SPEEDUP_FLOOR = 10.0     # acceptance: batched >= 10x event on the sweep
SCALE_PROCS = 1024
SCALE_BUDGET_S = 60.0    # acceptance: full registry time-tuning at scale

# JAX backend lanes (repro.sim.jax_backend)
JAX_PARITY_RTOL = 1e-6   # acceptance: jax-vs-numpy relative agreement
JAX_SPEEDUP_FLOOR = 2.0  # acceptance: jax >= 2x numpy on the 4096 sweep
JAX_SWEEP_PROCS = 4096   # beam-pricing sweep scale (arbitrary placements)
JAX_SWEEP_CANDS = 8      # seeded random permutations per app
JAX_SWEEP_REPS = 3       # timed repetitions (best-of; warm runs excluded)

# Pipeline lane (repro.search.pipeline)
PIPELINE_SPEEDUP_FLOOR = 1.3  # acceptance: pipelined >= 1.3x synchronous
PIPELINE_PROCS = 4096         # the random-placement sweep scale
PIPELINE_APPS = ("summa", "stencil")
PIPELINE_GROUPS = 12          # beam groups per app
PIPELINE_ROWS = 8             # random placements per group
PIPELINE_REPS = 3             # timed repetitions (best-of)

# Cache lane (repro.sim.price_cache)
CACHE_SPEEDUP_FLOOR = 5.0     # acceptance: warm re-tune >= 5x cold
CACHE_BENCH_PROCS = 2048      # registry scale for the cold/warm pair

# --scale lane (the 100k-proc suite)
FOLD_PARITY_PROCS = 4096      # folded == dense bit-equality probe scale
FOLD_PARITY_APPS = ("summa", "stencil", "cannon")
SCALE_REGISTRY_PROCS = 16384  # full registry must tune inside SCALE_BUDGET_S
SCALE_XL_PROCS = 131072       # one app must tune inside SCALE_BUDGET_S
SCALE_XL_APP = "stencil"      # 2^17 has no square grid; halo still factors


def _rank_agreement(report, app) -> float | None:
    """Fraction of leaderboard pairs with strictly different volumes whose
    simulated-time order agrees with the volume order."""
    rows = []
    for s in report.leaderboard:
        model = app.search_space.cost_model(report.procs, s.candidate.opts)
        try:
            rows.append((model.cost(s.candidate.grid), s.rank_cost))
        except ValueError:
            continue
    pairs = agree = 0
    for (va, ta), (vb, tb) in itertools.combinations(rows, 2):
        if va == vb:
            continue
        pairs += 1
        agree += (va < vb) == (ta < tb)
    return agree / pairs if pairs else None


def _tune_one(app, chips: int | None) -> dict:
    sim_app = time_tuned_app(app)
    rep_t = tune_app(sim_app, chips)
    rep_v = tune_app(app, chips)
    vol_model = app.search_space.cost_model(
        rep_t.procs, rep_t.best.candidate.opts
    )
    winner_volume = vol_model.cost(rep_t.best.candidate.grid)
    # The volume run's oracle is already feasibility-guarded by tune_app
    # (e.g. summa's square-grid pair at --chips 48 raises ValueError and
    # records None); the time run dropped its oracle (units mismatch).
    oracle = rep_v.oracle
    o_def, o_tuned = oracle if oracle is not None else (None, None)
    return {
        "app": app.name,
        "procs": rep_t.procs,
        "machine": list(rep_t.machine_shape),
        "sim_winner": rep_t.best.candidate.describe(),
        # The tuner batch-prices every surviving variant's ACTUAL
        # placement (Phase 3), so the winner's time is its placed time.
        "sim_winner_time_s": rep_t.best.placed_cost,
        "grid_default_time_s": rep_t.best.volume,
        "sim_winner_volume": winner_volume,
        "volume_winner": rep_v.best.candidate.describe(),
        "volume_best": rep_v.best.volume,
        "oracle_default": o_def,
        "oracle_tuned": o_tuned,
        "matches_tuned_oracle": (
            o_tuned is None or winner_volume <= o_tuned * (1 + 1e-9)
        ),
        "regresses_default": (
            o_def is not None and winner_volume > o_def * (1 + 1e-9)
        ),
        "rank_agreement": _rank_agreement(rep_t, app),
        "candidates_simulated": rep_t.candidates_considered,
        "elapsed_s": rep_t.elapsed_s,
    }


# ------------------------------------------------------------ engine lanes
def _candidate_sets(app, chips: int | None):
    """Every feasible (grid, options) point of one app with its default
    placement + all bijective tuner variants — the registry sweep both
    engines price."""
    sp_b = time_search_space(app)
    sp_e = time_search_space(app, engine="event")
    n = app.procs(chips)
    if not app.search_space.grids(n):
        n = app.default_procs
    shape = tuple(int(s) for s in app.machine_shape(n))
    for opts in app.search_space.option_combos():
        mb = sp_b.cost_model(n, dict(opts))
        me = sp_e.cost_model(n, dict(opts))
        for grid in app.search_space.grids(n):
            try:
                mb.base.cost(grid)
            except ValueError:
                continue
            cands = [mb._default_assignment(grid)]
            for c in app.search_space.variants(grid, tuple(opts), shape):
                prog = build_program(shape, c, "bench")
                a = prog.mapper.assignment_grid(c.grid, use_cache=False)
                flat = a.reshape(-1)
                if flat.size == n and len(np.unique(flat)) == n:
                    cands.append(np.asarray(a))
            yield mb, me, grid, np.stack(cands)


def engine_parity(report=print) -> dict:
    """Batched vs event per-step agreement on the paper cluster, all nine
    apps, every candidate placement."""
    worst = 0.0
    n_checked = 0
    for app in apps.iter_apps():
        for mb, me, grid, stack in _candidate_sets(app, None):
            t_batch = mb.price_assignments(grid, stack)
            t_event = me.price_assignments(grid, stack)
            worst = max(worst, float(np.abs(t_batch - t_event).max()))
            n_checked += len(stack)
    ok = worst <= ENGINE_ATOL
    report(f"engine parity (paper cluster): {n_checked} placements, "
           f"max |batch - event| = {worst:.3e} "
           f"({'OK' if ok else 'FAIL'} @ {ENGINE_ATOL:g})")
    return {"placements": n_checked, "max_abs_diff_s": worst,
            "atol": ENGINE_ATOL, "ok": ok}


def engine_bench(report=print, chips: int = CHIPS) -> dict:
    """The 64-chip registry sweep, batched (one grouped pricing pass)
    vs the event engine replaying each candidate."""
    stacks, event_work = [], []
    n_cands = 0
    for app in apps.iter_apps():
        for mb, me, grid, stack in _candidate_sets(app, chips):
            n_cands += len(stack)
            stacks.append((mb.beam_pricer(grid), stack))
            event_work.append((me, grid, stack))
    price_stacks(stacks)        # warm caches shared by both engines
    t0 = time.perf_counter()
    batch_res = price_stacks(stacks)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    event_res = [
        [me.simulate(grid, a.reshape(grid)).per_step_time() for a in stack]
        for me, grid, stack in event_work
    ]
    t_event = time.perf_counter() - t0
    worst = max(
        float(np.abs(tb - np.asarray(te)).max())
        for tb, te in zip(batch_res, event_res)
    )
    speedup = t_event / t_batch if t_batch > 0 else float("inf")
    report(f"engine sweep ({chips} chips): {n_cands} placements  "
           f"event {t_event * 1e3:8.1f}ms  batch {t_batch * 1e3:8.1f}ms  "
           f"speedup {speedup:6.1f}x (floor {SPEEDUP_FLOOR:.0f}x)  "
           f"max diff {worst:.2e}")
    return {
        "chips": chips,
        "placements": n_cands,
        "event_s": t_event,
        "batch_s": t_batch,
        "speedup": speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "max_abs_diff_s": worst,
        "ok": speedup >= SPEEDUP_FLOOR and worst <= ENGINE_ATOL,
    }


# ---------------------------------------------------------- jax backend
def jax_parity(report=print) -> dict:
    """The JAX engine vs the NumPy reference, registry-wide: every app,
    every (grid, options) point, default placement + every bijective
    tuner variant, against NumPy pricing with folding/incremental both
    ON and OFF. Relative agreement must stay within ``JAX_PARITY_RTOL``
    (the jax engine runs float64 — observed agreement is ~1e-15)."""
    from repro.sim import jax_backend

    if not jax_backend.have_jax():
        report("jax parity: jax unavailable (FAIL)")
        return {"available": False, "ok": False}
    worst = 0.0
    n_checked = 0
    for app in apps.iter_apps():
        for mb, me, grid, stack in _candidate_sets(app, None):
            jeng = jax_backend.to_jax(mb.beam_pricer(grid))
            t_jax = jeng.step_times(stack)
            eng = mb.beam_pricer(grid)
            for fold in (True, False):
                ref = eng.step_times(stack, fold=fold, incremental=fold)
                rel = np.abs(t_jax - ref) / np.maximum(np.abs(ref), 1e-300)
                worst = max(worst, float(rel.max()))
            n_checked += len(stack)
    ok = worst <= JAX_PARITY_RTOL
    report(f"jax parity (paper cluster): {n_checked} placements x "
           f"fold on/off, max rel |jax - numpy| = {worst:.3e} "
           f"({'OK' if ok else 'FAIL'} @ {JAX_PARITY_RTOL:g})")
    return {"available": True, "placements": n_checked,
            "max_rel_diff": worst, "rtol": JAX_PARITY_RTOL, "ok": ok}


def _balanced_grid(model_factory, app, procs: int):
    """The most balanced feasible grid of ``app`` at ``procs`` (minimal
    aspect ratio; the shape a tuner shortlists), or None."""
    best = None
    for grid in app.search_space.grids(procs):
        try:
            model_factory._validate(grid)
        except ValueError:
            continue
        key = (max(grid) / min(grid), grid)
        if best is None or key < best[0]:
            best = (key, grid)
    return None if best is None else best[1]


def jax_bench(report=print, procs: int = JAX_SWEEP_PROCS,
              n_cands: int = JAX_SWEEP_CANDS,
              reps: int = JAX_SWEEP_REPS) -> dict:
    """The committed beam-pricing sweep: each feasible registry app's
    most balanced grid at ``procs`` procs, priced for ``n_cands`` seeded
    *arbitrary* placements (uniform random permutations — the search
    workload an ASI-style proposer/evaluator loop generates, where the
    NumPy engine's symmetry folding and incremental re-pricing cannot
    fire), NumPy engine vs the compiled JAX engine, best of ``reps``
    after a warm run (schedule caches and jit compiles excluded from
    both sides). The aggregate speedup must stay above
    ``JAX_SPEEDUP_FLOOR``."""
    from repro.sim import jax_backend

    if not jax_backend.have_jax():
        report("jax bench: jax unavailable (FAIL)")
        return {"available": False, "ok": False}
    rng = np.random.default_rng(0)
    work = []
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        if not app.search_space.grids(procs):
            report(f"jax bench: {app.name} infeasible at {procs}; skipped")
            continue
        sp = time_search_space(app)
        opts = dict(next(iter(app.search_space.option_combos())))
        model = sp.cost_model(procs, opts)
        grid = _balanced_grid(model, app, procs)
        if grid is None:
            report(f"jax bench: {app.name} has no simulable grid; skipped")
            continue
        stack = np.stack([rng.permutation(procs) for _ in range(n_cands)])
        work.append((app.name, grid, model.batch(grid),
                     jax_backend.to_jax(model.batch(grid)), stack))

    def time_best(fn):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rows, worst = [], 0.0
    tot_np = tot_jax = 0.0
    for name, grid, eng, jeng, stack in work:
        ref = eng.step_times(stack)          # warm: schedule + fold probe
        got = jeng.step_times(stack)         # warm: export + jit compile
        rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-300)
        worst = max(worst, float(rel.max()))
        t_np = time_best(lambda: eng.step_times(stack))
        t_jax = time_best(lambda: jeng.step_times(stack))
        tot_np += t_np
        tot_jax += t_jax
        rows.append({"app": name, "grid": list(grid),
                     "numpy_s": t_np, "jax_s": t_jax,
                     "speedup": t_np / t_jax if t_jax > 0 else float("inf"),
                     "max_rel_diff": float(rel.max())})
    speedup = tot_np / tot_jax if tot_jax > 0 else float("inf")
    ok = (speedup >= JAX_SPEEDUP_FLOOR and worst <= JAX_PARITY_RTOL
          and bool(rows))
    report(f"\njax beam-pricing sweep ({procs} procs, {n_cands} arbitrary "
           f"placements/app, best of {reps}):")
    report(f"{'app':10s} {'grid':>14s} {'numpy_ms':>9s} {'jax_ms':>8s} "
           f"{'speedup':>8s}")
    for r in rows:
        gs = "x".join(str(g) for g in r["grid"])
        report(f"{r['app']:10s} {gs:>14s} {r['numpy_s'] * 1e3:9.1f} "
               f"{r['jax_s'] * 1e3:8.1f} {r['speedup']:7.2f}x")
    report(f"aggregate: numpy {tot_np * 1e3:.1f}ms  jax {tot_jax * 1e3:.1f}ms "
           f" speedup {speedup:.2f}x (floor {JAX_SPEEDUP_FLOOR:.0f}x)  "
           f"max rel diff {worst:.2e} ({'OK' if ok else 'FAIL'})")
    return {"available": True, "procs": procs, "cands_per_app": n_cands,
            "reps": reps, "apps": rows,
            "numpy_s": tot_np, "jax_s": tot_jax, "speedup": speedup,
            "speedup_floor": JAX_SPEEDUP_FLOOR, "max_rel_diff": worst,
            "rtol": JAX_PARITY_RTOL, "ok": ok}


# ------------------------------------------------------- pipeline + cache
class _DeviceHandle:
    """In-flight result of :class:`_SerialDevice`: blocks until the
    device model's completion deadline, then returns the real value."""

    __slots__ = ("_value", "_done_at")

    def __init__(self, value, done_at: float) -> None:
        self._value = value
        self._done_at = done_at

    def result(self):
        delay = self._done_at - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        return self._value


class _SerialDevice:
    """Serial-occupancy device model for the pipeline lane.

    Dispatch returns immediately (as JAX async dispatch does); each
    dispatched group occupies the device for ``busy_s`` starting when
    the previous group finishes, and ``result()`` blocks until that
    deadline. Values are the JAX engine's real step times, precomputed
    bit-exact per stack — the model changes *when* the host waits,
    never what it receives. See the module docstring for why the
    single-core CI box needs the emulation.
    """

    prices_independently = True

    def __init__(self, results: dict, busy_s: float) -> None:
        self._results = results
        self._busy_s = busy_s
        self._free_at = 0.0

    def reset(self) -> None:
        self._free_at = 0.0

    def step_times_async(self, stack, *, fold=True, incremental=True):
        start = max(time.monotonic(), self._free_at)
        self._free_at = done = start + self._busy_s
        return _DeviceHandle(self._results[stack.tobytes()], done)

    def step_times(self, stack, *, fold=True, incremental=True):
        return self.step_times_async(stack).result()


def pipeline_bench(report=print, procs: int = PIPELINE_PROCS,
                   n_groups: int = PIPELINE_GROUPS,
                   rows: int = PIPELINE_ROWS,
                   reps: int = PIPELINE_REPS) -> dict:
    """Streaming vs synchronous Phase 3 on the 4096-proc random-placement
    sweep: per group, the producer does the tuner's real host work
    (canonicalization + cache digests of ``rows`` random placements)
    while the device prices the previous group. Committed floor
    ``PIPELINE_SPEEDUP_FLOOR``; values must match the synchronous path
    bit for bit."""
    from repro.sim import jax_backend

    if not jax_backend.have_jax():
        report("pipeline bench: jax unavailable (FAIL)")
        return {"available": False, "ok": False}

    def expand(stacks, shape, device):
        """The tuner's per-group producer work, faithfully: canonical
        form + cache row digest for every placement in the group."""
        for stack in stacks:
            entries = [digest(canonical_assignment(row, shape).tobytes())
                       for row in stack]
            yield PriceJob(engine=device, stack=stack, entries=entries)

    def time_best(fn):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rng = np.random.default_rng(42)
    app_rows, match = [], True
    tot_sync = tot_pipe = 0.0
    for name in PIPELINE_APPS:
        app = _app_by_name(name)
        sp = time_search_space(app)
        opts = dict(next(iter(app.search_space.option_combos())))
        model = sp.cost_model(procs, opts)
        grid = _balanced_grid(model, app, procs)
        if grid is None:
            report(f"pipeline bench: {name} infeasible at {procs}; skipped")
            continue
        shape = tuple(int(s) for s in app.machine_shape(procs))
        jeng = jax_backend.to_jax(model.batch(grid))
        stacks = [np.stack([rng.permutation(procs) for _ in range(rows)])
                  for _ in range(n_groups)]
        # Real prices, computed once off the clock (on this box the XLA
        # "device" would otherwise time-slice with the producer thread).
        reals = {s.tobytes(): np.asarray(jeng.step_times(s))
                 for s in stacks}
        # Balanced device: busy window = measured per-group expansion
        # cost, so ideal overlap is 2x against the 1.3x floor.
        t0 = time.perf_counter()
        for _ in expand(stacks, shape, None):
            pass
        busy_s = (time.perf_counter() - t0) / n_groups
        device = _SerialDevice(reals, busy_s)

        def run_sync():
            device.reset()
            groups = list(expand(stacks, shape, device))  # expand all...
            return [price_job(job) for job in groups]     # ...then price

        def run_pipe():
            device.reset()
            return [t for _, t in stream_priced(expand(stacks, shape,
                                                       device))]

        expect = [reals[s.tobytes()] for s in stacks]
        match = match and all(
            np.array_equal(a, b) for a, b in zip(run_sync(), expect)
        ) and all(
            np.array_equal(a, b) for a, b in zip(run_pipe(), expect)
        )
        t_sync = time_best(run_sync)
        t_pipe = time_best(run_pipe)
        tot_sync += t_sync
        tot_pipe += t_pipe
        app_rows.append({"app": name, "grid": list(grid),
                         "busy_ms_per_group": busy_s * 1e3,
                         "sync_s": t_sync, "pipe_s": t_pipe,
                         "speedup": t_sync / t_pipe if t_pipe > 0
                         else float("inf")})
    speedup = tot_sync / tot_pipe if tot_pipe > 0 else float("inf")
    ok = speedup >= PIPELINE_SPEEDUP_FLOOR and match and bool(app_rows)
    report(f"\npipelined Phase 3 ({procs} procs, {n_groups} groups x "
           f"{rows} random placements, best of {reps}):")
    for r in app_rows:
        gs = "x".join(str(g) for g in r["grid"])
        report(f"{r['app']:10s} {gs:>14s} sync {r['sync_s'] * 1e3:7.1f}ms  "
               f"pipelined {r['pipe_s'] * 1e3:7.1f}ms  "
               f"speedup {r['speedup']:5.2f}x")
    report(f"aggregate: sync {tot_sync * 1e3:.1f}ms  pipelined "
           f"{tot_pipe * 1e3:.1f}ms  speedup {speedup:.2f}x "
           f"(floor {PIPELINE_SPEEDUP_FLOOR:.1f}x)  values match: {match} "
           f"({'OK' if ok else 'FAIL'})")
    return {"available": True, "procs": procs, "groups": n_groups,
            "rows": rows, "reps": reps, "emulated_device": True,
            "apps": app_rows, "sync_s": tot_sync, "pipe_s": tot_pipe,
            "speedup": speedup, "speedup_floor": PIPELINE_SPEEDUP_FLOOR,
            "values_match": match, "ok": ok}


def cache_bench(report=print, procs: int = CACHE_BENCH_PROCS) -> dict:
    """Cold vs warm time-domain tuning of the full registry through one
    persistent price-cache directory. The warm pass starts from a fresh
    :class:`PriceCache` instance with every in-process cache cleared —
    only the on-disk tables carry over — and must serve every placement
    from them (hits > 0, writes == 0), reproduce the cold leaderboards
    exactly, and beat ``CACHE_SPEEDUP_FLOOR``."""
    root = Path(tempfile.mkdtemp(prefix="price-cache-bench-"))
    names = [a.name for a in apps.iter_apps()
             if a.search_space is not None and a.collective is not None]
    try:
        clear_caches()
        cold_cache = PriceCache(root)
        t0 = time.perf_counter()
        cold = {n: tune_app(time_tuned_app(apps.get(n), cache=cold_cache),
                            procs) for n in names}
        t_cold = time.perf_counter() - t0
        cold_stats = cold_cache.stats()
        clear_caches()
        warm_cache = PriceCache(root)
        t0 = time.perf_counter()
        warm = {n: tune_app(time_tuned_app(apps.get(n), cache=warm_cache),
                            procs) for n in names}
        t_warm = time.perf_counter() - t0
        warm_stats = warm_cache.stats()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    reports_match = all(
        [s.placed_cost for s in cold[n].leaderboard]
        == [s.placed_cost for s in warm[n].leaderboard]
        for n in names
    )
    speedup = t_cold / t_warm if t_warm > 0 else float("inf")
    ok = (speedup >= CACHE_SPEEDUP_FLOOR and warm_stats["hits"] > 0
          and warm_stats["writes"] == 0 and reports_match)
    report(f"\nprice cache ({procs} procs, {len(names)} apps): cold "
           f"{t_cold:.2f}s ({cold_stats['writes']} rows written)  warm "
           f"{t_warm:.2f}s ({warm_stats['hits']} hits, "
           f"{warm_stats['writes']} writes)  speedup {speedup:.1f}x "
           f"(floor {CACHE_SPEEDUP_FLOOR:.0f}x)  leaderboards match: "
           f"{reports_match} ({'OK' if ok else 'FAIL'})")
    return {"procs": procs, "apps": names,
            "cold_s": t_cold, "warm_s": t_warm, "speedup": speedup,
            "speedup_floor": CACHE_SPEEDUP_FLOOR,
            "cold_writes": cold_stats["writes"],
            "warm_hits": warm_stats["hits"],
            "warm_writes": warm_stats["writes"],
            "reports_match": reports_match, "ok": ok}


def scale_bench(report=print, procs: int = SCALE_PROCS) -> dict:
    """time_tuned_app over the full registry at scale, against the
    CI-enforced wall-clock budget."""
    rows = []
    t0 = time.perf_counter()
    for app in apps.iter_apps():
        t1 = time.perf_counter()
        rep = tune_app(time_tuned_app(app), procs)
        rows.append({
            "app": app.name,
            "procs": rep.procs,
            "winner": rep.best.candidate.describe(),
            "winner_time_s": rep.best.placed_cost,
            "candidates": rep.candidates_considered,
            "variants": rep.variants_evaluated,
            "verified": rep.verified,
            "elapsed_s": time.perf_counter() - t1,
        })
    elapsed = time.perf_counter() - t0
    report(f"\ntime-domain tuning at {procs} procs "
           f"({elapsed:.2f}s, budget {SCALE_BUDGET_S:.0f}s):")
    report(f"{'app':10s} {'procs':>6s} {'winner':28s} {'time_s':>10s} "
           f"{'cands':>6s} {'elapsed':>8s}")
    for r in rows:
        report(f"{r['app']:10s} {r['procs']:6d} {r['winner']:28s} "
               f"{r['winner_time_s']:10.3e} {r['candidates']:6d} "
               f"{r['elapsed_s']:7.2f}s")
    return {
        "procs": procs,
        "apps": rows,
        "elapsed_s": elapsed,
        "budget_s": SCALE_BUDGET_S,
        "within_budget": elapsed < SCALE_BUDGET_S,
        "all_verified": all(r["verified"] for r in rows),
    }


def _app_by_name(name: str):
    for app in apps.iter_apps():
        if app.name == name:
            return app
    raise KeyError(name)


def fold_parity(report=print, procs: int = FOLD_PARITY_PROCS) -> dict:
    """Symmetry-folded + incremental pricing vs dense pricing, bit-equal,
    for every candidate placement of the probe apps at ``procs`` — and
    the fold must actually fire (otherwise this lane proves nothing)."""
    with fold_stats() as stats:
        worst_exact, n_checked = _fold_parity_sweep(procs)
    ok = worst_exact and stats["pairs_folded"] > 0
    report(f"fold parity ({procs} procs): {n_checked} placements, "
           f"folded == dense bit-equal: {worst_exact}, "
           f"pairs folded {stats['pairs_folded']} / "
           f"priced {stats['pairs_priced']} "
           f"({'OK' if ok else 'FAIL'})")
    return {"procs": procs, "apps": list(FOLD_PARITY_APPS),
            "placements": n_checked, "bit_equal": worst_exact,
            "fold_stats": dict(stats), "ok": ok}


def _fold_parity_sweep(procs: int) -> tuple[bool, int]:
    worst_exact = True
    n_checked = 0
    for name in FOLD_PARITY_APPS:
        app = _app_by_name(name)
        sp = time_search_space(app)
        shape = tuple(int(s) for s in app.machine_shape(procs))
        for opts in app.search_space.option_combos():
            model = sp.cost_model(procs, dict(opts))
            for grid in app.search_space.grids(procs):
                try:
                    model._validate(grid)
                except ValueError:
                    continue
                cands = [model._default_assignment(grid)]
                for c in app.search_space.variants(grid, tuple(opts), shape):
                    prog = build_program(shape, c, "scale_bench")
                    a = prog.mapper.assignment_grid(c.grid, use_cache=False)
                    flat = a.reshape(-1)
                    if flat.size == procs and len(np.unique(flat)) == procs:
                        cands.append(np.asarray(a))
                stack = np.stack(cands)
                eng = model.batch(grid)
                t_fold = eng.step_times(stack)
                t_dense = eng.step_times(stack, fold=False, incremental=False)
                worst_exact = worst_exact and bool(
                    np.array_equal(t_fold, t_dense))
                n_checked += len(stack)
    return worst_exact, n_checked


def xl_bench(report=print, procs: int = SCALE_XL_PROCS,
             app_name: str = SCALE_XL_APP) -> dict:
    """One app time-tuned at 100k+ procs against the wall-clock budget."""
    app = _app_by_name(app_name)
    t0 = time.perf_counter()
    rep = tune_app(time_tuned_app(app), procs)
    elapsed = time.perf_counter() - t0
    ok = elapsed < SCALE_BUDGET_S and rep.verified
    report(f"XL tuning: {app_name} at {procs} procs -> "
           f"{rep.best.candidate.describe()} "
           f"({rep.best.placed_cost:.3e}s/step) in {elapsed:.2f}s "
           f"(budget {SCALE_BUDGET_S:.0f}s, {'OK' if ok else 'FAIL'})")
    return {"app": app_name, "procs": procs,
            "winner": rep.best.candidate.describe(),
            "winner_time_s": rep.best.placed_cost,
            "candidates": rep.candidates_considered,
            "verified": rep.verified,
            "elapsed_s": elapsed, "budget_s": SCALE_BUDGET_S,
            "within_budget": elapsed < SCALE_BUDGET_S}


def scale_suite(report=print) -> dict:
    """The --scale deliverable: fold parity, the 16384-proc registry
    sweep, and the 131072-proc XL lane."""
    return {
        "fold_parity": fold_parity(report),
        "registry": scale_bench(report, SCALE_REGISTRY_PROCS),
        "xl": xl_bench(report),
    }


def run(report=print, chips: int = CHIPS, quick: bool = False,
        scale_procs: int = SCALE_PROCS,
        json_path: str | None = "BENCH_sim.json") -> dict:
    t0 = time.perf_counter()
    paper_rows, scaled_rows = [], []
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        paper_rows.append(_tune_one(app, None))
        if not quick:
            scaled_rows.append(_tune_one(app, chips))
    elapsed = time.perf_counter() - t0

    def table(rows, title):
        report(f"\n{title}")
        report(f"{'app':10s} {'procs':>5s} {'sim winner':22s} "
               f"{'time_s':>10s} {'volume':>11s} {'oracle_tuned':>12s} "
               f"{'match':>6s} {'agree':>6s}")
        for r in rows:
            agree = ("  -" if r["rank_agreement"] is None
                     else f"{r['rank_agreement']:.2f}")
            tuned = ("           -" if r["oracle_tuned"] is None
                     else f"{r['oracle_tuned']:12.4g}")
            report(f"{r['app']:10s} {r['procs']:5d} {r['sim_winner']:22s} "
                   f"{r['sim_winner_time_s']:10.3e} "
                   f"{r['sim_winner_volume']:11.4g} "
                   f"{tuned} "
                   f"{str(r['matches_tuned_oracle']):>6s} {agree:>6s}")

    table(paper_rows, "paper scale (Table 2 clusters)")
    if scaled_rows:
        table(scaled_rows, f"benchmark scale ({chips} chips)")
    report(f"\ntuning sweep: {elapsed:.2f}s (budget {TIME_BUDGET_S:.0f}s)")

    parity = engine_parity(report)
    j_parity = jax_parity(report)
    engines = None if quick else engine_bench(report, chips)
    j_bench = None if quick else jax_bench(report)
    p_bench = None if quick else pipeline_bench(report)
    c_bench = None if quick else cache_bench(report)
    scale = None if quick else scale_bench(report, scale_procs)

    agreements = [
        r["rank_agreement"] for r in paper_rows + scaled_rows
        if r["rank_agreement"] is not None
    ]
    result = {
        "chips": chips,
        "quick": quick,
        "paper_scale": paper_rows,
        "benchmark_scale": scaled_rows,
        "elapsed_s": elapsed,
        "time_budget_s": TIME_BUDGET_S,
        "within_budget": elapsed < TIME_BUDGET_S,
        # Acceptance: simulated-time winners match the Table 2 tuning
        # oracle for every registry app at the paper's cluster scale...
        "all_match_tuned_oracle": all(
            r["matches_tuned_oracle"] for r in paper_rows
        ),
        # ...and never regress the untuned default volume anywhere.
        "any_default_regression": any(
            r["regresses_default"] for r in paper_rows + scaled_rows
        ),
        "mean_rank_agreement": (
            sum(agreements) / len(agreements) if agreements else None
        ),
        "engine_parity": parity,
        "jax_parity": j_parity,
        "engine_bench": engines,
        "jax_bench": j_bench,
        "pipeline_bench": p_bench,
        "cache_bench": c_bench,
        "scale_bench": scale,
    }
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        report(f"wrote {json_path}")
    return result


def check(result: dict) -> list[str]:
    """Acceptance gates over a run's (or a loaded BENCH_sim.json's)
    result — shared by main() and the CI perf-regression lane."""
    errors = []
    # .get-guarded: a --scale-only run merges into (or stands in for) a
    # full run's JSON, so the full-run keys may be absent.
    if not result.get("all_match_tuned_oracle", True):
        errors.append("a simulated-time winner missed the Table 2 tuning "
                      "oracle at paper scale")
    if result.get("any_default_regression", False):
        errors.append("a simulated-time winner regressed the untuned "
                      "default volume")
    if result.get("mean_rank_agreement") is not None \
            and result["mean_rank_agreement"] < MIN_AGREEMENT:
        errors.append(f"sim-vs-volume ranking agreement "
                      f"{result['mean_rank_agreement']:.2f} < {MIN_AGREEMENT}")
    if not result.get("within_budget", True):
        errors.append(f"tuning sweep took {result['elapsed_s']:.2f}s "
                      f"(budget {result['time_budget_s']:.0f}s)")
    parity = result.get("engine_parity")
    if parity is not None and not parity["ok"]:
        errors.append(f"batched engine diverged from the event engine by "
                      f"{parity['max_abs_diff_s']:.3e}s "
                      f"(> {ENGINE_ATOL:g})")
    jp = result.get("jax_parity")
    if jp is not None:
        if not jp.get("available", False):
            errors.append("the jax backend is unavailable (the parity lane "
                          "requires jax)")
        elif not jp["ok"]:
            errors.append(f"jax engine diverged from the numpy engine by "
                          f"{jp['max_rel_diff']:.3e} relative "
                          f"(> {JAX_PARITY_RTOL:g})")
    jb = result.get("jax_bench")
    if jb is not None:
        if not jb.get("available", False):
            errors.append("the jax backend is unavailable (the speedup lane "
                          "requires jax)")
        else:
            if jb["speedup"] < jb["speedup_floor"]:
                errors.append(
                    f"jax beam-pricing speedup {jb['speedup']:.2f}x fell "
                    f"below the committed {jb['speedup_floor']:.0f}x floor")
            if jb["max_rel_diff"] > jb["rtol"]:
                errors.append(f"jax sweep diverged by "
                              f"{jb['max_rel_diff']:.3e} relative "
                              f"(> {jb['rtol']:g})")
    pb = result.get("pipeline_bench")
    if pb is not None:
        if not pb.get("available", False):
            errors.append("the jax backend is unavailable (the pipeline "
                          "lane requires jax)")
        else:
            if pb["speedup"] < pb["speedup_floor"]:
                errors.append(
                    f"pipelined Phase 3 speedup {pb['speedup']:.2f}x fell "
                    f"below the committed {pb['speedup_floor']:.1f}x floor")
            if not pb["values_match"]:
                errors.append("the pipelined Phase 3 returned different "
                              "step times than the synchronous path")
    cb = result.get("cache_bench")
    if cb is not None:
        if cb["speedup"] < cb["speedup_floor"]:
            errors.append(
                f"warm-cache re-tune speedup {cb['speedup']:.1f}x fell "
                f"below the committed {cb['speedup_floor']:.0f}x floor")
        if cb["warm_hits"] <= 0 or cb["warm_writes"] > 0:
            errors.append("the warm re-tune did not serve every placement "
                          "from the persistent price cache")
        if not cb["reports_match"]:
            errors.append("warm-cache tuning changed a leaderboard")
    eng = result.get("engine_bench")
    if eng is not None and eng["speedup"] < eng["speedup_floor"]:
        errors.append(f"batched-engine speedup {eng['speedup']:.1f}x fell "
                      f"below the committed {eng['speedup_floor']:.0f}x floor")
    if eng is not None and eng["max_abs_diff_s"] > ENGINE_ATOL:
        errors.append(f"engine sweep diverged by "
                      f"{eng['max_abs_diff_s']:.3e}s (> {ENGINE_ATOL:g})")
    scale = result.get("scale_bench")
    if scale is not None and not scale["within_budget"]:
        errors.append(f"registry tuning at {scale['procs']} procs took "
                      f"{scale['elapsed_s']:.2f}s "
                      f"(budget {scale['budget_s']:.0f}s)")
    if scale is not None and not scale["all_verified"]:
        errors.append(f"a {scale['procs']}-proc winner failed DSL "
                      f"verification")
    suite = result.get("scale_suite")
    if suite is not None:
        fp = suite["fold_parity"]
        if not fp["bit_equal"]:
            errors.append(f"folded pricing diverged from dense pricing at "
                          f"{fp['procs']} procs (must be bit-equal)")
        if fp["fold_stats"]["pairs_folded"] <= 0:
            errors.append("symmetry folding never fired on the fold-parity "
                          "probe apps")
        reg = suite["registry"]
        if not reg["within_budget"]:
            errors.append(f"registry tuning at {reg['procs']} procs took "
                          f"{reg['elapsed_s']:.2f}s "
                          f"(budget {reg['budget_s']:.0f}s)")
        if not reg["all_verified"]:
            errors.append(f"a {reg['procs']}-proc winner failed DSL "
                          f"verification")
        xl = suite["xl"]
        if not xl["within_budget"]:
            errors.append(f"XL tuning ({xl['app']} at {xl['procs']} procs) "
                          f"took {xl['elapsed_s']:.2f}s "
                          f"(budget {xl['budget_s']:.0f}s)")
        if not xl["verified"]:
            errors.append(f"the {xl['procs']}-proc XL winner failed DSL "
                          f"verification")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chips", type=int, default=CHIPS)
    ap.add_argument("--scale-procs", type=int, default=SCALE_PROCS,
                    help="processor count for the scale lane")
    ap.add_argument("--quick", action="store_true",
                    help="paper-scale tuning + engine parity only "
                         "(the CI sim-smoke lane)")
    ap.add_argument("--scale", action="store_true",
                    help="run the 100k-proc scale suite (fold parity, "
                         "16384-proc registry, 131072-proc XL) and merge "
                         "it into --json")
    ap.add_argument("--json", default="BENCH_sim.json",
                    help="output path for the machine-readable results")
    args = ap.parse_args(argv)

    if args.scale:
        # Merge into an existing full-run artifact when present, so the
        # CI perf-regression lane sees one BENCH_sim.json with both.
        path = Path(args.json) if args.json else None
        result = (json.loads(path.read_text())
                  if path is not None and path.exists() else {})
        result["scale_suite"] = scale_suite()
        if path is not None:
            path.write_text(json.dumps(result, indent=2) + "\n")
            print(f"wrote {path}")
    else:
        result = run(chips=args.chips, quick=args.quick,
                     scale_procs=args.scale_procs, json_path=args.json)
    errors = check(result)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
