"""Simulator evaluation: time-domain tuning vs the Table 2 volume oracles.

For every registry application this harness runs the mapper autotuner
TWICE — once with the app's analytic volume objective (the PR-3 search)
and once with the discrete-event simulator as the objective
(``repro.sim.cost.time_tuned_app``, same tuner, same search space, cost
in predicted seconds) — and enforces:

  * **paper scale** (each app's default 2-node cluster, where the paper's
    Table 2 pairs live): the simulated-time winner's communication volume
    matches the Table 2 tuning oracle (<= the hand-tuned volume) for
    every registry app;
  * **benchmark scale** (``--chips``, default 64): the time winner never
    regresses the oracle's *default* (untuned) volume. Halo apps may
    legitimately diverge from the *tuned* volume here: the simulator
    prices the max-port bottleneck, under which equally-NIC-loaded
    placements tie and fewer messages win, while the volume model counts
    total (mostly intra-node) traffic — the divergence is reported per
    app (see docs/simulator.md);
  * **ranking agreement**: across each app's leaderboard, the fraction of
    strictly-volume-ordered candidate pairs whose simulated times agree
    in order (recorded; enforced >= 0.5 registry-wide on the apps with
    more than one candidate);
  * **speed budget**: the full double-tuning sweep (every app, both
    scales, every candidate simulated) completes within 10 s.

Writes ``BENCH_sim.json`` (the CI artifact next to ``BENCH_mapping.json``
and ``BENCH_tuning.json``). ``--quick`` runs the paper scale only.

    PYTHONPATH=src python benchmarks/sim_eval.py --json BENCH_sim.json
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro import apps
from repro.search.tuner import tune_app
from repro.sim.cost import time_search_space, time_tuned_app

CHIPS = 64
TIME_BUDGET_S = 10.0     # acceptance: full-registry simulation budget
MIN_AGREEMENT = 0.5


def _rank_agreement(report, app) -> float | None:
    """Fraction of leaderboard pairs with strictly different volumes whose
    simulated-time order agrees with the volume order."""
    rows = []
    for s in report.leaderboard:
        model = app.search_space.cost_model(report.procs, s.candidate.opts)
        try:
            rows.append((model.cost(s.candidate.grid), s.volume))
        except ValueError:
            continue
    pairs = agree = 0
    for (va, ta), (vb, tb) in itertools.combinations(rows, 2):
        if va == vb:
            continue
        pairs += 1
        agree += (va < vb) == (ta < tb)
    return agree / pairs if pairs else None


def _tune_one(app, chips: int | None) -> dict:
    sim_app = time_tuned_app(app)
    rep_t = tune_app(sim_app, chips)
    rep_v = tune_app(app, chips)
    vol_model = app.search_space.cost_model(
        rep_t.procs, rep_t.best.candidate.opts
    )
    winner_volume = vol_model.cost(rep_t.best.candidate.grid)
    # The tuner scores each grid at its default placement (Phase 1);
    # re-simulate the winning candidate's ACTUAL assignment grid so the
    # reported time corresponds to the placement that won.
    time_model = time_search_space(app).cost_model(
        rep_t.procs, rep_t.best.candidate.opts
    )
    winner_assign = np.asarray(rep_t.best_program.mapper.assignment_grid(
        rep_t.best.candidate.grid
    ))
    placed_time = time_model.simulate(
        rep_t.best.candidate.grid, winner_assign
    ).per_step_time()
    # The volume run's oracle is already feasibility-guarded by tune_app
    # (e.g. summa's square-grid pair at --chips 48 raises ValueError and
    # records None); the time run dropped its oracle (units mismatch).
    oracle = rep_v.oracle
    o_def, o_tuned = oracle if oracle is not None else (None, None)
    return {
        "app": app.name,
        "procs": rep_t.procs,
        "machine": list(rep_t.machine_shape),
        "sim_winner": rep_t.best.candidate.describe(),
        "sim_winner_time_s": placed_time,
        "grid_default_time_s": rep_t.best.volume,
        "sim_winner_volume": winner_volume,
        "volume_winner": rep_v.best.candidate.describe(),
        "volume_best": rep_v.best.volume,
        "oracle_default": o_def,
        "oracle_tuned": o_tuned,
        "matches_tuned_oracle": (
            o_tuned is None or winner_volume <= o_tuned * (1 + 1e-9)
        ),
        "regresses_default": (
            o_def is not None and winner_volume > o_def * (1 + 1e-9)
        ),
        "rank_agreement": _rank_agreement(rep_t, app),
        "candidates_simulated": rep_t.candidates_considered,
        "elapsed_s": rep_t.elapsed_s,
    }


def run(report=print, chips: int = CHIPS, quick: bool = False,
        json_path: str | None = "BENCH_sim.json") -> dict:
    t0 = time.perf_counter()
    paper_rows, scaled_rows = [], []
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        paper_rows.append(_tune_one(app, None))
        if not quick:
            scaled_rows.append(_tune_one(app, chips))
    elapsed = time.perf_counter() - t0

    def table(rows, title):
        report(f"\n{title}")
        report(f"{'app':10s} {'procs':>5s} {'sim winner':22s} "
               f"{'time_s':>10s} {'volume':>11s} {'oracle_tuned':>12s} "
               f"{'match':>6s} {'agree':>6s}")
        for r in rows:
            agree = ("  -" if r["rank_agreement"] is None
                     else f"{r['rank_agreement']:.2f}")
            tuned = ("           -" if r["oracle_tuned"] is None
                     else f"{r['oracle_tuned']:12.4g}")
            report(f"{r['app']:10s} {r['procs']:5d} {r['sim_winner']:22s} "
                   f"{r['sim_winner_time_s']:10.3e} "
                   f"{r['sim_winner_volume']:11.4g} "
                   f"{tuned} "
                   f"{str(r['matches_tuned_oracle']):>6s} {agree:>6s}")

    table(paper_rows, "paper scale (Table 2 clusters)")
    if scaled_rows:
        table(scaled_rows, f"benchmark scale ({chips} chips)")
    report(f"\nfull sweep: {elapsed:.2f}s (budget {TIME_BUDGET_S:.0f}s)")

    agreements = [
        r["rank_agreement"] for r in paper_rows + scaled_rows
        if r["rank_agreement"] is not None
    ]
    result = {
        "chips": chips,
        "quick": quick,
        "paper_scale": paper_rows,
        "benchmark_scale": scaled_rows,
        "elapsed_s": elapsed,
        "time_budget_s": TIME_BUDGET_S,
        "within_budget": elapsed < TIME_BUDGET_S,
        # Acceptance: simulated-time winners match the Table 2 tuning
        # oracle for every registry app at the paper's cluster scale...
        "all_match_tuned_oracle": all(
            r["matches_tuned_oracle"] for r in paper_rows
        ),
        # ...and never regress the untuned default volume anywhere.
        "any_default_regression": any(
            r["regresses_default"] for r in paper_rows + scaled_rows
        ),
        "mean_rank_agreement": (
            sum(agreements) / len(agreements) if agreements else None
        ),
    }
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        report(f"wrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chips", type=int, default=CHIPS)
    ap.add_argument("--quick", action="store_true",
                    help="paper scale only (the CI sim-smoke lane)")
    ap.add_argument("--json", default="BENCH_sim.json",
                    help="output path for the machine-readable results")
    args = ap.parse_args(argv)

    result = run(chips=args.chips, quick=args.quick, json_path=args.json)
    ok = True
    if not result["all_match_tuned_oracle"]:
        print("ERROR: a simulated-time winner missed the Table 2 tuning "
              "oracle at paper scale", file=sys.stderr)
        ok = False
    if result["any_default_regression"]:
        print("ERROR: a simulated-time winner regressed the untuned "
              "default volume", file=sys.stderr)
        ok = False
    if result["mean_rank_agreement"] is not None \
            and result["mean_rank_agreement"] < MIN_AGREEMENT:
        print(f"ERROR: sim-vs-volume ranking agreement "
              f"{result['mean_rank_agreement']:.2f} < {MIN_AGREEMENT}",
              file=sys.stderr)
        ok = False
    if not result["within_budget"]:
        print(f"ERROR: simulation sweep took {result['elapsed_s']:.2f}s "
              f"(budget {TIME_BUDGET_S:.0f}s)", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
