"""Microbenchmark: vectorized vs per-point mapper grid evaluation.

Quantifies the mapping-IR refactor (docs/mapping_ir.md): every
``Mapper.assignment_grid`` call evaluates the mapping function over the
whole iteration grid in ONE batched pass of NumPy index arithmetic
(``ProcSpace.to_root_batch``) instead of one Python call per iteration
point. This harness times both paths on production-size tile grids,
verifies they are bit-identical, and cross-checks every registry app's
device permutation between the two paths.

    PYTHONPATH=src python benchmarks/mapping_eval.py            # full
    PYTHONPATH=src python benchmarks/mapping_eval.py --quick    # CI smoke

Writes ``BENCH_mapping.json`` (override with ``--json``). In full mode the
headline case — a 64x64x64 iteration grid — must reach a >=50x speedup or
the script exits non-zero; bit-identity failures always exit non-zero.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro import apps
from repro.core import (
    GPU,
    Machine,
    block_cyclic_mapper,
    block_mapper,
    cyclic_mapper,
    hierarchical_block_mapper,
    linearize_cyclic_mapper,
)

SPEEDUP_TARGET = 50.0        # acceptance floor for the 64^3 headline case
HEADLINE = "cyclic3d_64x64x64"


def _cases(quick: bool):
    """(name, mapper, ispace) benchmark cases; headline last for the log."""
    g2 = (16, 16) if quick else (64, 64)
    g3 = (16, 16, 16) if quick else (64, 64, 64)
    m2 = Machine(GPU, shape=(4, 4))
    m3 = Machine(GPU, shape=(4, 4, 4))
    tag2 = "x".join(map(str, g2))
    tag3 = "x".join(map(str, g3))
    return [
        (f"block2d_{tag2}", block_mapper(m2, "block2d"), g2),
        (f"blockcyclic2d_{tag2}", block_cyclic_mapper(m2, "blockcyclic2d"), g2),
        (f"hierarchical2d_{tag2}",
         hierarchical_block_mapper(m2, g2, "hierarchical2d"), g2),
        (f"linearize_cyclic2d_{tag2}",
         linearize_cyclic_mapper(m2, "linearize_cyclic2d"), g2),
        (f"cyclic3d_{tag3}", cyclic_mapper(m3, "cyclic3d"), g3),
    ]


def _time_once(fn) -> tuple[float, np.ndarray]:
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def bench_cases(quick: bool, report=print) -> list[dict]:
    rows = []
    report(f"{'case':28s} {'points':>9s} {'scalar_ms':>10s} "
           f"{'batched_ms':>10s} {'cached_us':>9s} {'speedup':>8s} {'equal':>5s}")
    for name, mapper, ispace in _cases(quick):
        t_scalar, g_scalar = _time_once(
            lambda: mapper.assignment_grid(
                ispace, vectorized=False, use_cache=False)
        )
        t_batch, g_batch = _time_once(
            lambda: mapper.assignment_grid(ispace, use_cache=False)
        )
        path = mapper.last_eval_path
        mapper.assignment_grid(ispace)                       # prime the cache
        t_cached, _ = _time_once(lambda: mapper.assignment_grid(ispace))
        equal = bool(np.array_equal(g_scalar, g_batch))
        speedup = t_scalar / t_batch if t_batch > 0 else float("inf")
        rows.append({
            "case": name,
            "points": int(np.prod(ispace)),
            "scalar_ms": t_scalar * 1e3,
            "batched_ms": t_batch * 1e3,
            "cached_us": t_cached * 1e6,
            "speedup": speedup,
            "bit_identical": equal,
            "path": path,
        })
        report(f"{name:28s} {rows[-1]['points']:9d} {t_scalar*1e3:10.1f} "
               f"{t_batch*1e3:10.2f} {t_cached*1e6:9.1f} {speedup:8.1f} "
               f"{str(equal):>5s}")
    return rows


def check_registry_apps(report=print) -> list[dict]:
    """Every registry app's device permutation, scalar vs batched path."""
    rows = []
    for app in apps.iter_apps():
        for procs in (app.default_procs, 64):
            try:
                grid = app.tile_grid(procs)
            except ValueError:
                continue
            mapper = app.mapper(procs)
            scalar = mapper.assignment_grid(
                grid, vectorized=False, use_cache=False).reshape(-1)
            batched = mapper.assignment_grid(grid, use_cache=False).reshape(-1)
            rows.append({
                "app": app.name,
                "procs": procs,
                "grid": list(grid),
                "bit_identical": bool(np.array_equal(scalar, batched)),
                "path": mapper.last_eval_path,
            })
    bad = [r for r in rows if not r["bit_identical"]]
    fell_back = [r["app"] for r in rows if r["path"] != "vectorized"]
    report(f"registry permutations: {len(rows)} checked, "
           f"{len(rows) - len(bad)} bit-identical, "
           f"{len(rows) - len(fell_back)} vectorized"
           + (f"; MISMATCH: {bad}" if bad else "")
           + (f"; FELL BACK: {fell_back}" if fell_back else ""))
    return rows


def run(quick: bool = True, report=print) -> dict:
    cases = bench_cases(quick, report)
    app_rows = check_registry_apps(report)
    headline = next((r for r in cases if r["case"] == HEADLINE), None)
    result = {
        "mode": "quick" if quick else "full",
        "speedup_target": SPEEDUP_TARGET,
        "headline": headline,
        "cases": cases,
        "registry_apps": app_rows,
        "all_bit_identical": all(
            r["bit_identical"] for r in cases + app_rows
        ),
        # The headline property is that these mappers actually VECTORIZE;
        # bit-identity alone would pass vacuously if a regression made every
        # evaluation fall back to the per-point interpreter.
        "all_vectorized": all(
            r["path"] == "vectorized" for r in cases + app_rows
        ),
    }
    if headline is not None:
        report(f"headline {HEADLINE}: {headline['speedup']:.1f}x "
               f"(target >= {SPEEDUP_TARGET:.0f}x)")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small grids for the CI smoke lane (no speedup floor)")
    ap.add_argument("--json", default="BENCH_mapping.json",
                    help="output path for the machine-readable results")
    args = ap.parse_args(argv)

    result = run(quick=args.quick)
    Path(args.json).write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.json}")

    if not result["all_bit_identical"]:
        print("ERROR: batched path diverges from per-point path",
              file=sys.stderr)
        return 1
    if not result["all_vectorized"]:
        print("ERROR: a vectorizable mapper fell back to the per-point "
              "interpreter (see 'path' fields)", file=sys.stderr)
        return 1
    headline = result["headline"]
    if not args.quick and headline is not None \
            and headline["speedup"] < SPEEDUP_TARGET:
        print(f"ERROR: headline speedup {headline['speedup']:.1f}x "
              f"< {SPEEDUP_TARGET:.0f}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
