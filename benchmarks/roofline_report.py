"""Roofline table from the dry-run artifacts (assignment deliverable g).

Reads results/dryrun_baseline.json (or $ROOFLINE_PATH) and prints, per
(arch x shape x mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS, and HBM fit. ``compare()`` prints baseline vs
optimized side by side.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core import machine as hw

_RESULTS = Path(__file__).resolve().parent.parent / "results"
DEFAULT_PATH = Path(os.environ.get("ROOFLINE_PATH",
                                   _RESULTS / "dryrun_baseline.json"))
OPTIMIZED_PATH = _RESULTS / "dryrun_optimized.json"


def load(path=DEFAULT_PATH):
    return json.loads(Path(path).read_text())


def hbm_total(rec) -> float:
    m = rec.get("memory_analysis", {})
    return (
        m.get("argument_size_in_bytes", 0)
        + m.get("temp_size_in_bytes", 0)
        + m.get("output_size_in_bytes", 0)
        - m.get("alias_size_in_bytes", 0)
    )


def run(report=print, path=DEFAULT_PATH) -> dict:
    recs = [r for r in load(path) if r["status"] == "ok"]
    report(
        f"{'arch':22s} {'shape':12s} {'mesh':7s} {'mode':5s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
        f"{'bound':>10s} {'useful':>7s} {'HBM_GiB':>8s} {'fits':>5s}"
    )
    n_fit = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rt = r["roofline"]
        hbm = hbm_total(r) / 2**30
        fits = hbm <= hw.HBM_BYTES / 2**30
        n_fit += fits
        report(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:7s} "
            f"{r.get('sharding_mode', '?'):5s} "
            f"{rt['compute_s']:10.3e} {rt['memory_s']:10.3e} "
            f"{rt['collective_s']:10.3e} {rt['bottleneck']:>10s} "
            f"{rt['useful_flops_ratio']:7.2f} {hbm:8.2f} "
            f"{'y' if fits else 'N':>5s}"
        )
    skipped = [r for r in load(path) if r["status"] == "skipped"]
    report(f"\n{len(recs)} cells ok, {len(skipped)} skipped "
           f"(long_500k on full-attention archs), {n_fit}/{len(recs)} fit "
           f"in {hw.HBM_BYTES / 2**30:.0f} GiB HBM")
    if path == DEFAULT_PATH and OPTIMIZED_PATH.exists():
        compare(report)
    return {"ok": len(recs), "skipped": len(skipped), "fit": n_fit}


def _dominant(rt) -> float:
    return max(rt["compute_s"], rt["memory_s"], rt["collective_s"])


def compare(report=print, base_path=None, opt_path=OPTIMIZED_PATH) -> dict:
    """Baseline vs optimized: dominant-term speedup + HBM-fit per cell."""
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in load(base_path or _RESULTS / "dryrun_baseline.json")
            if r["status"] == "ok"}
    opt = {(r["arch"], r["shape"], r["mesh"]): r
           for r in load(opt_path) if r["status"] == "ok"}
    report("\n--- baseline vs optimized (dominant roofline term) ---")
    report(f"{'cell':45s} {'base_s':>10s} {'opt_s':>10s} {'speedup':>8s} "
           f"{'fit b->o':>9s}")
    gains = []
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        tb, to = _dominant(b["roofline"]), _dominant(o["roofline"])
        fit_b = hbm_total(b) <= hw.HBM_BYTES
        fit_o = hbm_total(o) <= hw.HBM_BYTES
        sp = tb / max(to, 1e-12)
        gains.append(sp)
        if sp > 1.05 or sp < 0.95 or fit_b != fit_o:
            report(f"{'x'.join(key):45s} {tb:10.3e} {to:10.3e} {sp:7.1f}x "
                   f"{('y' if fit_b else 'N')}->{('y' if fit_o else 'N'):>4s}")
    import math

    gm = math.exp(sum(math.log(max(g, 1e-9)) for g in gains) / len(gains))
    n_fit_o = sum(hbm_total(r) <= hw.HBM_BYTES for r in opt.values())
    report(f"\ngeomean dominant-term speedup over {len(gains)} cells: "
           f"{gm:.2f}x; optimized HBM fit: {n_fit_o}/{len(opt)}")
    return {"geomean": gm, "cells": len(gains)}


if __name__ == "__main__":
    run()
