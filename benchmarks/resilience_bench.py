"""Fault-recovery benchmark -> ``BENCH_resilience.json``.

Two lanes, both gated (the committed floors fail CI on regression):

**remap** — recovery latency and quality across the registry at
``BENCH_PROCS`` processors. Per app, the healthy plan is tuned once,
then two failure scenarios hit the machine:

* *node-death*: one processor is masked dead — the stale placement is
  impossible (prices ``inf``) and the plan must move;
* *contention*: background traffic halves one NIC's bandwidth — the
  stale placement still runs, just slower.

Each scenario is remapped twice: ``mode="warm"`` (beam seeded with the
refit stale winner, Phase 1 restricted to those points) and
``mode="cold"`` (full enumeration on the surviving sub-machine). The
two timings use *twin* failures — symmetric but distinct (a different
dead processor / contended port) — so both modes face a first-encounter
degradation and neither inherits the other's freshly warmed cache rows;
recovery latency is exactly the first-response regime. Gates:

* aggregate warm recovery latency beats cold retune by
  >= ``REMAP_WARM_FLOOR`` x (measured ~4.5x; 3x leaves noise room);
* every remapped placement puts **zero** work on dead processors;
* the remapped plan's degraded step time is never worse than keeping
  the stale placement on the degraded machine.

**parity** — the degraded-pricing contracts, registry-wide: a
mask/contention-free :class:`~repro.core.machine.DegradedMachine` is
bit-identical to the healthy path through all three engines (event,
batched NumPy, batched JAX), and under port contention the batched
envelope tracks the event queue to <= ``PARITY_TOL``.

    PYTHONPATH=src python benchmarks/resilience_bench.py
    PYTHONPATH=src python benchmarks/resilience_bench.py --procs 64
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import apps                                    # noqa: E402
from repro.core.machine import DegradedMachine            # noqa: E402
from repro.search.remap import remap_plan                 # noqa: E402
from repro.search.tuner import tune_app                   # noqa: E402
from repro.sim.cost import (                              # noqa: E402
    SimulatedTimeCostModel,
    spec_for,
    time_tuned_app,
)

#: Acceptance: aggregate warm-remap recovery latency must beat the cold
#: retune baseline by at least this factor across the registry.
REMAP_WARM_FLOOR = 3.0

#: Acceptance: batched-vs-event agreement under degradation.
PARITY_TOL = 1e-9

#: Remap lane scale — large enough that a cold retune's enumeration is
#: real work, small enough for CI.
BENCH_PROCS = 256

#: Contended-NIC slowdown factor for the contention scenario.
CONTENTION_FACTOR = 2.0


def _twin_failures(spec) -> dict[str, tuple[DegradedMachine, DegradedMachine]]:
    """Two symmetric-but-distinct degradations per scenario, so the
    warm- and cold-timed remaps each see a first-encounter failure."""
    level = 0 if int(spec.shape[0]) >= 2 else 1
    return {
        "node-death": (
            DegradedMachine.fail_procs(spec, [spec.nprocs - 1]),
            DegradedMachine.fail_procs(spec, [spec.nprocs - 2]),
        ),
        "contention": (
            DegradedMachine.contend(spec, level, {0: CONTENTION_FACTOR}),
            DegradedMachine.contend(spec, level, {1: CONTENTION_FACTOR}),
        ),
    }


def remap_bench(report=print, procs: int = BENCH_PROCS) -> dict:
    """Warm vs cold recovery latency + remap quality, registry-wide."""
    rows = []
    t_warm = t_cold = 0.0
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        if not app.search_space.grids(procs):
            continue
        spec = spec_for(app.machine_shape(procs))
        stale = tune_app(time_tuned_app(app), procs)
        for scenario, (fail_w, fail_c) in _twin_failures(spec).items():
            t0 = time.perf_counter()
            warm = remap_plan(app, stale, fail_w, mode="warm", procs=procs)
            dt_w = time.perf_counter() - t0
            t0 = time.perf_counter()
            cold = remap_plan(app, stale, fail_c, mode="cold", procs=procs)
            dt_c = time.perf_counter() - t0
            t_warm += dt_w
            t_cold += dt_c
            dead = set(warm.degraded.dead_procs)
            clean = not dead.intersection(
                int(p) for p in warm.placement.reshape(-1))
            not_worse = warm.degraded_step_s <= warm.stale_step_s * (1 + 1e-9)
            rows.append({
                "app": app.name, "scenario": scenario,
                "warm_s": dt_w, "cold_s": dt_c,
                "warm_procs": warm.procs, "cold_procs": cold.procs,
                "degraded_step_s": warm.degraded_step_s,
                "stale_step_s": warm.stale_step_s,
                "placement_avoids_dead": clean,
                "not_worse_than_stale": not_worse,
            })
    speedup = t_cold / t_warm if t_warm > 0 else float("inf")
    all_clean = all(r["placement_avoids_dead"] for r in rows)
    all_not_worse = all(r["not_worse_than_stale"] for r in rows)
    ok = speedup >= REMAP_WARM_FLOOR and all_clean and all_not_worse
    report(f"\nfault remap ({len(rows)} app x scenario rows, {procs} procs): "
           f"warm {t_warm:.2f}s  cold {t_cold:.2f}s  "
           f"speedup {speedup:.1f}x (floor {REMAP_WARM_FLOOR:.0f}x)  "
           f"dead-proc-free: {all_clean}  never-worse-than-stale: "
           f"{all_not_worse} ({'OK' if ok else 'FAIL'})")
    return {
        "procs": procs,
        "rows": rows,
        "warm_s": t_warm,
        "cold_s": t_cold,
        "speedup": speedup,
        "speedup_floor": REMAP_WARM_FLOOR,
        "placement_avoids_dead": all_clean,
        "not_worse_than_stale": all_not_worse,
        "ok": ok,
    }


def parity_bench(report=print) -> dict:
    """Degraded-pricing parity contracts across the registry."""
    rows = []
    for app in apps.iter_apps():
        if app.search_space is None or app.collective is None:
            continue
        n = app.default_procs
        spec = spec_for(app.machine_shape(n))
        space = app.search_space
        grid = space.default_grid(n) if space.default_grid \
            else space.grids(n)[0]
        trivial_identical = True
        for engine in ("batched", "event", "batched-jax"):
            model = SimulatedTimeCostModel(
                pattern=app.collective, spec=spec,
                step_flops=float(app.step_flops(n)), engine=engine)
            triv = SimulatedTimeCostModel(
                pattern=app.collective, spec=spec,
                step_flops=float(app.step_flops(n)), engine=engine,
                degraded=DegradedMachine.healthy(spec))
            if triv.cost(grid) != model.cost(grid):
                trivial_identical = False
        deg = DegradedMachine.contend(spec, 0, {0: 2.5})
        if len(spec.shape) > 1:
            deg = deg.merged(DegradedMachine.contend(spec, 1, {1: 1.5}))
        batched = SimulatedTimeCostModel(
            pattern=app.collective, spec=spec,
            step_flops=float(app.step_flops(n)), degraded=deg)
        event = SimulatedTimeCostModel(
            pattern=app.collective, spec=spec,
            step_flops=float(app.step_flops(n)), engine="event",
            degraded=deg)
        assign = batched._default_assignment(grid)
        tb = batched.batch(grid).step_time(assign)
        te = event.simulate(grid, assign).per_step_time()
        rows.append({
            "app": app.name,
            "trivial_bit_identical": trivial_identical,
            "degraded_abs_diff_s": abs(tb - te),
        })
    all_identical = all(r["trivial_bit_identical"] for r in rows)
    max_abs = max(r["degraded_abs_diff_s"] for r in rows)
    ok = all_identical and max_abs <= PARITY_TOL
    report(f"degraded parity ({len(rows)} apps): trivial bit-identical "
           f"across 3 engines: {all_identical}, contended batched-vs-event "
           f"max |diff| {max_abs:.2e}s (tol {PARITY_TOL:.0e}) "
           f"({'OK' if ok else 'FAIL'})")
    return {
        "apps": rows,
        "trivial_bit_identical": all_identical,
        "max_abs_diff_s": max_abs,
        "tol": PARITY_TOL,
        "ok": ok,
    }


def run(report=print, procs: int = BENCH_PROCS,
        json_path: str | None = "BENCH_resilience.json") -> dict:
    result = {
        "remap": remap_bench(report, procs),
        "parity": parity_bench(report),
    }
    result["ok"] = result["remap"]["ok"] and result["parity"]["ok"]
    if json_path:
        Path(json_path).write_text(json.dumps(result, indent=2) + "\n")
        report(f"wrote {json_path}")
    return result


def check(result: dict) -> list[str]:
    """Acceptance gates over a run's (or a loaded BENCH_resilience.json's)
    result — shared by main() and the CI perf-regression lane."""
    errors = []
    rm = result.get("remap")
    if rm is not None:
        if rm["speedup"] < rm["speedup_floor"]:
            errors.append(
                f"warm remap speedup {rm['speedup']:.1f}x fell below the "
                f"committed {rm['speedup_floor']:.0f}x floor")
        for r in rm["rows"]:
            if not r["placement_avoids_dead"]:
                errors.append(f"{r['app']}/{r['scenario']}: remapped "
                              f"placement touches a dead processor")
            if not r["not_worse_than_stale"]:
                errors.append(
                    f"{r['app']}/{r['scenario']}: remapped plan "
                    f"({r['degraded_step_s']:.3e}s) is slower than the "
                    f"stale placement ({r['stale_step_s']:.3e}s)")
    pa = result.get("parity")
    if pa is not None:
        if not pa["trivial_bit_identical"]:
            errors.append("a trivial DegradedMachine priced differently "
                          "from the healthy path")
        if pa["max_abs_diff_s"] > pa["tol"]:
            errors.append(
                f"contended batched-vs-event diff {pa['max_abs_diff_s']:.2e}s "
                f"exceeds the {pa['tol']:.0e}s tolerance")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--procs", type=int, default=BENCH_PROCS)
    ap.add_argument("--json", default="BENCH_resilience.json", metavar="PATH")
    args = ap.parse_args(argv)
    result = run(procs=args.procs, json_path=args.json)
    errors = check(result)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
