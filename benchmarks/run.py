"""Benchmark driver — one harness per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run --only loc_table
  PYTHONPATH=src python -m benchmarks.run --only mapper_tuning --only sim_eval

Prints a ``name,us_per_call,derived`` CSV at the end (microbench section)
plus the per-table reports above it. The ``mapper_tuning`` and
``sim_eval`` lanes write ``BENCH_tuning.json`` / ``BENCH_sim.json``
(uploaded as CI artifacts next to ``BENCH_mapping.json``); the
``roofline`` and ``perf_iterations`` sections read previously recorded
dry-run artifacts and skip cleanly when absent.

Every run additionally aggregates the executed sections' results — each
harness's ``run()`` returns its machine-readable artifact — into one
top-level ``BENCH_perf.json`` trajectory file (machine info + per-section
timings + results), so the perf history of whatever ran is recorded per
PR instead of living only in scattered CI uploads. ``--perf-json ''``
disables it.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

from benchmarks import (
    decompose_sweep,
    heuristic_gap,
    loc_table,
    mapper_tuning,
    mapping_eval,
    perf_iterations,
    resilience_bench,
    roofline_report,
    serve_bench,
    sim_eval,
)

SECTIONS = {
    "loc_table": ("Table 1: mapper LoC, Mapple vs low-level", loc_table.run),
    "mapper_tuning": ("Table 2: mapper tuning headroom (autotuner search)",
                      mapper_tuning.run),
    "heuristic_gap": ("Heuristic gap: greedy baseline vs tuner optimum "
                      "(+ Fig 13 locality)", heuristic_gap.run),
    "decompose_sweep": ("Figs 14-17: decompose vs Algorithm 1 (180 configs)",
                        decompose_sweep.run),
    "mapping_eval": ("Mapping IR: vectorized vs per-point grid evaluation",
                     mapping_eval.run),
    "sim_eval": ("Simulator: time-domain tuning, engine parity/speedup, "
                 "1024-proc scale (+ BENCH_sim.json)", sim_eval.run),
    "serve_bench": ("Tuning service: cold vs warm trace replay + "
                    "warm-started search (+ BENCH_serve.json)",
                    serve_bench.run),
    "resilience_bench": ("Fault recovery: warm remap vs cold retune + "
                         "degraded-pricing parity (+ BENCH_resilience.json)",
                         resilience_bench.run),
    "roofline": ("Roofline table (from dry-run artifacts)",
                 roofline_report.run),
    "perf_iterations": ("§Perf hillclimb summary (from recorded artifacts)",
                        perf_iterations.run),
}

PERF_JSON = "BENCH_perf.json"


def machine_info() -> dict:
    import os

    info = {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "processor": platform.processor(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax

        info["jax"] = {
            "version": jax.__version__,
            "backend": jax.default_backend(),
            "devices": [str(d) for d in jax.devices()],
        }
    except Exception as e:  # noqa: BLE001 - record why jax is absent
        info["jax"] = {"unavailable": str(e)}
    return info


def _trajectory(sections: dict) -> dict:
    """The headline number(s) of each executed section — the compact
    cross-PR comparison block at the top of ``BENCH_perf.json`` (diff
    this against the previous PR's instead of spelunking the full
    per-section results)."""
    headline: dict = {}
    for key, entry in sections.items():
        if "skipped" in entry:
            continue
        res = entry.get("result")
        row: dict = {"elapsed_s": round(entry.get("elapsed_s", 0.0), 3)}
        if key == "sim_eval" and isinstance(res, dict):
            eng = res.get("engine_bench") or {}
            jb = res.get("jax_bench") or {}
            jp = res.get("jax_parity") or {}
            par = res.get("engine_parity") or {}
            pb = res.get("pipeline_bench") or {}
            cb = res.get("cache_bench") or {}
            row.update({
                "batched_vs_event_speedup": eng.get("speedup"),
                "jax_vs_numpy_speedup": jb.get("speedup"),
                "pipeline_vs_sync_speedup": pb.get("speedup"),
                "warm_cache_speedup": cb.get("speedup"),
                "jax_parity_max_rel": jp.get("max_rel_diff"),
                "engine_parity_max_abs_s": par.get("max_abs_diff_s"),
                "mean_rank_agreement": res.get("mean_rank_agreement"),
            })
        elif key == "serve_bench" and isinstance(res, dict):
            rp = res.get("replay") or {}
            row.update({
                "warm_replay_speedup": rp.get("speedup"),
                "cold_p99_s": rp.get("cold_p99_s"),
                "warm_p99_s": rp.get("warm_p99_s"),
                "warm_start_ok": (res.get("warm_start") or {}).get("ok"),
            })
        elif key == "resilience_bench" and isinstance(res, dict):
            rm = res.get("remap") or {}
            pa = res.get("parity") or {}
            row.update({
                "warm_remap_speedup": rm.get("speedup"),
                "remap_quality_ok": (rm.get("placement_avoids_dead")
                                     and rm.get("not_worse_than_stale")),
                "degraded_parity_max_abs_s": pa.get("max_abs_diff_s"),
            })
        elif key == "mapping_eval" and isinstance(res, dict):
            row["speedup"] = res.get("speedup")
        elif key == "mapper_tuning" and isinstance(res, dict):
            row["all_oracles_rediscovered"] = res.get(
                "all_oracles_rediscovered")
        elif key == "microbench" and isinstance(res, list):
            row["us_per_call"] = {
                r["name"]: round(r["us_per_call"], 1) for r in res
            }
        headline[key] = {k: v for k, v in row.items() if v is not None}
    return headline


def write_perf_trajectory(sections: dict, path: str = PERF_JSON,
                          report=print) -> dict:
    """Aggregate executed sections into the per-PR perf trajectory file."""
    payload = {
        "machine": machine_info(),
        "trajectory": _trajectory(sections),
        "sections": sections,
    }
    Path(path).write_text(json.dumps(payload, indent=2, default=str) + "\n")
    report(f"\nwrote {path} ({len(sections)} section(s))")
    return payload


def microbench(report=print) -> list[tuple[str, float, str]]:
    """Core-op timings: name, us_per_call, derived."""
    import jax.numpy as jnp

    from repro.core import GPU, Machine, block_mapper
    from repro.core.decompose import optimal_factorization
    from repro.kernels import ops

    rows = []

    def timeit(name, fn, n=20, derived=""):
        fn()  # warmup
        t0 = time.perf_counter()
        out = None
        for _ in range(n):
            out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((name, us, derived))

    timeit("decompose_solve_256x3",
           lambda: optimal_factorization(256, (8192, 8192, 64)),
           derived="optimal factorization; 3 dims")
    m = Machine(GPU, shape=(16, 16))
    mapper = block_mapper(m)
    timeit("mapper_eval_grid_16x16",
           lambda: mapper.assignment_grid((16, 16), use_cache=False),
           derived="256-point tile->device evaluation (vectorized, uncached)")
    timeit("mapper_eval_grid_16x16_cached",
           lambda: mapper.assignment_grid((16, 16)),
           derived="cache hit (the to_spmd steady state)")
    a = jnp.ones((256, 256), jnp.float32)
    b = jnp.ones((256, 256), jnp.float32)
    timeit("pallas_matmul_256_interp", lambda: ops.matmul(a, b), n=3,
           derived="interpret-mode (correctness path)")
    timeit("jnp_matmul_256", lambda: (a @ b), n=50,
           derived="XLA:CPU reference")
    f = jnp.ones((512, 512), jnp.float32)
    timeit("pallas_stencil_512_interp", lambda: ops.stencil_step(f), n=3,
           derived="interpret-mode")

    report("\nname,us_per_call,derived")
    for name, us, derived in rows:
        report(f"{name},{us:.1f},{derived}")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=None,
                    choices=list(SECTIONS),
                    help="run only the named section(s); repeatable")
    ap.add_argument("--perf-json", default=PERF_JSON,
                    help="aggregate trajectory output path ('' disables)")
    args = ap.parse_args(argv)
    keys = args.only if args.only else list(SECTIONS)
    results: dict = {}
    for key in keys:
        title, fn = SECTIONS[key]
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            result = fn()
        except FileNotFoundError as e:
            print(f"(skipped: {e} — run repro.launch.dryrun first)")
            results[key] = {"skipped": str(e)}
            continue
        results[key] = {
            "elapsed_s": time.perf_counter() - t0,
            "result": result,
        }
    if args.only is None:
        print(f"\n{'=' * 72}\nMicrobenchmarks\n{'=' * 72}")
        t0 = time.perf_counter()
        rows = microbench()
        results["microbench"] = {
            "elapsed_s": time.perf_counter() - t0,
            "result": [
                {"name": n, "us_per_call": us, "derived": d}
                for n, us, d in rows
            ],
        }
    if args.perf_json:
        write_perf_trajectory(results, args.perf_json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
