"""Benchmark driver — one harness per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run           # everything
  PYTHONPATH=src python -m benchmarks.run --only loc_table
  PYTHONPATH=src python -m benchmarks.run --only mapper_tuning  # + BENCH_tuning.json

Prints a ``name,us_per_call,derived`` CSV at the end (microbench section)
plus the per-table reports above it. The ``mapper_tuning`` and
``sim_eval`` lanes write ``BENCH_tuning.json`` / ``BENCH_sim.json``
(uploaded as CI artifacts next to ``BENCH_mapping.json``); the
``roofline`` and ``perf_iterations`` sections read previously recorded
dry-run artifacts and skip cleanly when absent.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    decompose_sweep,
    heuristic_gap,
    loc_table,
    mapper_tuning,
    mapping_eval,
    perf_iterations,
    roofline_report,
    sim_eval,
)

SECTIONS = {
    "loc_table": ("Table 1: mapper LoC, Mapple vs low-level", loc_table.run),
    "mapper_tuning": ("Table 2: mapper tuning headroom (autotuner search)",
                      mapper_tuning.run),
    "heuristic_gap": ("Heuristic gap: greedy baseline vs tuner optimum "
                      "(+ Fig 13 locality)", heuristic_gap.run),
    "decompose_sweep": ("Figs 14-17: decompose vs Algorithm 1 (180 configs)",
                        decompose_sweep.run),
    "mapping_eval": ("Mapping IR: vectorized vs per-point grid evaluation",
                     mapping_eval.run),
    "sim_eval": ("Simulator: time-domain tuning vs the Table 2 volume "
                 "oracles (+ BENCH_sim.json)", sim_eval.run),
    "roofline": ("Roofline table (from dry-run artifacts)",
                 roofline_report.run),
    "perf_iterations": ("§Perf hillclimb summary (from recorded artifacts)",
                        perf_iterations.run),
}


def microbench(report=print) -> list[tuple[str, float, str]]:
    """Core-op timings: name, us_per_call, derived."""
    import jax.numpy as jnp

    from repro.core import GPU, Machine, block_mapper
    from repro.core.decompose import optimal_factorization
    from repro.kernels import ops

    rows = []

    def timeit(name, fn, n=20, derived=""):
        fn()  # warmup
        t0 = time.perf_counter()
        out = None
        for _ in range(n):
            out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((name, us, derived))

    timeit("decompose_solve_256x3",
           lambda: optimal_factorization(256, (8192, 8192, 64)),
           derived="optimal factorization; 3 dims")
    m = Machine(GPU, shape=(16, 16))
    mapper = block_mapper(m)
    timeit("mapper_eval_grid_16x16",
           lambda: mapper.assignment_grid((16, 16), use_cache=False),
           derived="256-point tile->device evaluation (vectorized, uncached)")
    timeit("mapper_eval_grid_16x16_cached",
           lambda: mapper.assignment_grid((16, 16)),
           derived="cache hit (the to_spmd steady state)")
    a = jnp.ones((256, 256), jnp.float32)
    b = jnp.ones((256, 256), jnp.float32)
    timeit("pallas_matmul_256_interp", lambda: ops.matmul(a, b), n=3,
           derived="interpret-mode (correctness path)")
    timeit("jnp_matmul_256", lambda: (a @ b), n=50,
           derived="XLA:CPU reference")
    f = jnp.ones((512, 512), jnp.float32)
    timeit("pallas_stencil_512_interp", lambda: ops.stencil_step(f), n=3,
           derived="interpret-mode")

    report("\nname,us_per_call,derived")
    for name, us, derived in rows:
        report(f"{name},{us:.1f},{derived}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, choices=list(SECTIONS))
    args = ap.parse_args()
    keys = [args.only] if args.only else list(SECTIONS)
    for key in keys:
        title, fn = SECTIONS[key]
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")
        try:
            fn()
        except FileNotFoundError as e:
            print(f"(skipped: {e} — run repro.launch.dryrun first)")
    if args.only is None:
        print(f"\n{'=' * 72}\nMicrobenchmarks\n{'=' * 72}")
        microbench()


if __name__ == "__main__":
    main()
