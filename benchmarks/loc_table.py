"""Table 1 reproduction: mapper lines-of-code, Mapple vs low-level.

Iterates the unified application registry (``repro.apps``): each app's
Mapple program LoC (the paper's non-blank, non-comment convention, via
``MapperProgram.loc()``) is compared against its hand-written raw-JAX
baseline fixture in ``benchmarks/lowlevel/*_raw.py``, and the two are
verified to express the SAME mapping by comparing device-assignment grids
at the fixture's machine scale. Run with ``PYTHONPATH=src``.
"""
from __future__ import annotations

import importlib.util

import numpy as np

from repro import apps


def load_raw(app: "apps.Application"):
    """Import an app's low-level baseline fixture module."""
    path = app.lowlevel_path()
    spec = importlib.util.spec_from_file_location(f"{app.name}_raw", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    return mod


def verify_same_mapping(app: "apps.Application") -> bool:
    """Mapple program and raw fixture must produce identical device grids."""
    raw = load_raw(app)
    try:
        mapper = app.mapper()
        dsl_grid = mapper.assignment_grid(raw.GRID_SHAPE)
    except Exception:
        return False
    raw_grid = raw.assignment_grid(raw.GRID_SHAPE, raw.MACHINE_SHAPE)
    return bool(np.array_equal(raw_grid, dsl_grid))


def run(report=print) -> dict:
    rows = []
    for app in apps.iter_apps():
        mapple_loc = app.mapple_loc()
        raw_loc = app.lowlevel_loc()
        same = verify_same_mapping(app)
        rows.append((app.name, mapple_loc, raw_loc, raw_loc / mapple_loc,
                     same))
    report(f"{'app':12s} {'mapple':>7s} {'low-level':>10s} {'ratio':>7s} "
           f"{'same-map':>9s}")
    for name, m, r, ratio, same in rows:
        report(f"{name:12s} {m:7d} {r:10d} {ratio:7.1f} {str(same):>9s}")
    avg_m = sum(r[1] for r in rows) / len(rows)
    avg_r = sum(r[2] for r in rows) / len(rows)
    report(f"{'AVG':12s} {avg_m:7.1f} {avg_r:10.1f} {avg_r / avg_m:7.1f}")
    return {
        "rows": rows,
        "avg_mapple": avg_m,
        "avg_lowlevel": avg_r,
        "reduction": avg_r / avg_m,
    }


if __name__ == "__main__":
    run()
