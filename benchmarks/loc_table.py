"""Table 1 reproduction: mapper lines-of-code, Mapple vs low-level.

Counts non-blank, non-comment lines (the paper's convention) of each
application's Mapple program (benchmarks/mapple_programs/*.mapple) against
its hand-written raw-JAX counterpart (benchmarks/lowlevel/*_raw.py), and
verifies both express the SAME mapping by comparing device assignments.
"""
from __future__ import annotations

import importlib.util
import sys
from pathlib import Path

import numpy as np

HERE = Path(__file__).parent
APPS = [
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma",
    "circuit", "stencil", "pennant",
]


def count_loc(path: Path) -> int:
    out = 0
    in_docstring = False
    for raw in path.read_text().splitlines():
        ln = raw.strip()
        if not ln:
            continue
        if ln.startswith('"""') or ln.endswith('"""'):
            quote_count = ln.count('"""')
            if quote_count == 1:
                in_docstring = not in_docstring
            continue
        if in_docstring or ln.startswith("#"):
            continue
        out += 1
    return out


def load_raw(app: str):
    path = HERE / "lowlevel" / f"{app}_raw.py"
    spec = importlib.util.spec_from_file_location(f"{app}_raw", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    return mod


def verify_same_mapping(app: str) -> bool:
    """Mapple program and raw module must produce identical device grids."""
    from repro.core import dsl

    raw = load_raw(app)
    src = (HERE / "mapple_programs" / f"{app}.mapple").read_text()
    prog = dsl.parse(src)
    mapper = next(iter(prog.mappers.values()))
    grid_shape = raw.GRID_SHAPE
    raw_grid = raw.assignment_grid(grid_shape, raw.MACHINE_SHAPE)
    try:
        dsl_grid = mapper.assignment_grid(grid_shape)
    except Exception:
        return False
    return bool(np.array_equal(raw_grid, dsl_grid))


def run(report=print) -> dict:
    rows = []
    for app in APPS:
        mapple_loc = count_loc(HERE / "mapple_programs" / f"{app}.mapple")
        raw_loc = count_loc(HERE / "lowlevel" / f"{app}_raw.py")
        same = verify_same_mapping(app)
        rows.append((app, mapple_loc, raw_loc, raw_loc / mapple_loc, same))
    report(f"{'app':12s} {'mapple':>7s} {'low-level':>10s} {'ratio':>7s} "
           f"{'same-map':>9s}")
    for app, m, r, ratio, same in rows:
        report(f"{app:12s} {m:7d} {r:10d} {ratio:7.1f} {str(same):>9s}")
    avg_m = sum(r[1] for r in rows) / len(rows)
    avg_r = sum(r[2] for r in rows) / len(rows)
    report(f"{'AVG':12s} {avg_m:7.1f} {avg_r:10.1f} {avg_r / avg_m:7.1f}")
    return {
        "rows": rows,
        "avg_mapple": avg_m,
        "avg_lowlevel": avg_r,
        "reduction": avg_r / avg_m,
    }


if __name__ == "__main__":
    run()
