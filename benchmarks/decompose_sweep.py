"""Figs. 14-17 reproduction: decompose vs Algorithm 1 over Table 3's grid.

The exact 180-configuration parameter space of the paper (Sec. 6.3):
  * aspect ratio x:y in 1:1 .. 1:32,
  * iteration area per node in 1e6 .. 4e8,
  * GPUs in 4 .. 128 (4 per node);
improvement = halo-communication-volume reduction of the optimal
factorization over the greedy heuristic — the quantity Sec. 4.2 proves
drives the end-to-end stencil speedups the paper measures (0-83%,
geomean 16% on hardware).

The sweep runs once per halo-pattern application in the unified registry
(stencil, PENNANT), using each app's per-point flops and exchanged-field
count, so new halo workloads join the sweep by registering themselves.
Run with ``PYTHONPATH=src``.
"""
from __future__ import annotations

import math

from repro import apps
from repro.core.commvolume import halo_surface_volume
from repro.core.decompose import (
    greedy_factorization,
    optimal_factorization,
)

ASPECTS = [1, 2, 4, 8, 16, 32]
AREAS = [10**6, 10**7, 10**8, 2 * 10**8, 4 * 10**8]
GPUS = [4, 8, 16, 32, 64, 128]
GPUS_PER_NODE = 4


def iteration_space(aspect: int, area_per_node: int, n_gpus: int
                    ) -> tuple[int, int]:
    nodes = max(n_gpus // GPUS_PER_NODE, 1)
    total = area_per_node * nodes
    x = int(math.sqrt(total / aspect))
    y = x * aspect
    return max(x, 1), max(y, 1)


def modeled_step_time(lengths, factors, n_gpus, *, flops_per_point=5.0,
                      fields=1) -> float:
    """End-to-end sweep-time model on the v5e fabric: bandwidth-bound
    stencil compute + halo exchange on ICI/DCI. This is what turns the
    scale-invariant volume ratio into the paper's Fig. 16/17 trends
    (bigger per-node area -> comm matters less; more nodes -> DCI hops)."""
    from repro.core import machine as hw

    area = lengths[0] * lengths[1]
    compute = (area / n_gpus) * flops_per_point * 4 / hw.HBM_BW  # 4B reads
    v = halo_surface_volume(lengths, factors) * 4 * fields       # bytes
    nodes = max(n_gpus // GPUS_PER_NODE, 1)
    # fraction of cut surface crossing node boundaries ~ 1 - 1/nodes
    cross = v * (1.0 - 1.0 / nodes)
    intra = v - cross
    comm = intra / (n_gpus * hw.ICI_BW_PER_LINK) + cross / (
        nodes * hw.DCI_BW_PER_CHIP * GPUS_PER_NODE
    )
    return compute + comm


def one_config(aspect, area, gpus, *, flops_per_point=5.0, fields=1) -> dict:
    lengths = iteration_space(aspect, area, gpus)
    opt = optimal_factorization(gpus, lengths)
    gre = greedy_factorization(gpus, 2)
    v_opt = halo_surface_volume(lengths, opt) * fields
    v_gre = halo_surface_volume(lengths, gre) * fields
    improvement = (v_gre - v_opt) / max(v_gre, 1e-9) * 100.0
    kw = dict(flops_per_point=flops_per_point, fields=fields)
    t_opt = modeled_step_time(lengths, opt, gpus, **kw)
    t_gre = modeled_step_time(lengths, gre, gpus, **kw)
    return {
        "aspect": aspect, "area": area, "gpus": gpus,
        "lengths": lengths, "opt": opt, "greedy": gre,
        "v_opt": v_opt, "v_greedy": v_gre, "improvement_pct": improvement,
        "t_opt": t_opt, "t_greedy": t_gre,
        "time_improvement_pct": (t_gre - t_opt) / max(t_gre, 1e-12) * 100.0,
    }


def geomean_improvement(rows) -> float:
    """Geometric mean of the volume ratios, expressed as % improvement."""
    logs = [math.log(max(r["v_greedy"], 1e-9) / max(r["v_opt"], 1e-9))
            for r in rows]
    return (math.exp(sum(logs) / len(logs)) - 1.0) * 100.0


def _gm_time(rows) -> float:
    logs = [math.log(max(r["t_greedy"], 1e-12) / max(r["t_opt"], 1e-12))
            for r in rows]
    return (1.0 - math.exp(-sum(logs) / len(logs))) * 100.0


def sweep_app(app, report=print) -> dict:
    fpp = float(app.meta.get("flops_per_point", 5.0))
    fields = int(app.meta.get("halo_fields", 1))
    rows = [one_config(a, ar, g, flops_per_point=fpp, fields=fields)
            for a in ASPECTS for ar in AREAS for g in GPUS]
    imps = sorted(r["improvement_pct"] for r in rows)
    timps = sorted(r["time_improvement_pct"] for r in rows)
    report(f"--- {app.name}: {len(rows)} configs (paper: 180), "
           f"{fields} halo field(s), {fpp:.0f} flops/pt")
    report(f"comm-volume reduction: min {imps[0]:.1f}%  "
           f"median {imps[len(imps) // 2]:.1f}%  max {imps[-1]:.1f}%")
    report(f"modeled step-time improvement: min {timps[0]:.1f}%  "
           f"median {timps[len(timps) // 2]:.1f}%  max {timps[-1]:.1f}%  "
           f"(paper: 0-83%, geomean 16%)")
    report(f"geomean modeled improvement: {_gm_time(rows):.1f}%")
    report("by aspect ratio (Fig. 15, modeled time):")
    for a in ASPECTS:
        sub = [r for r in rows if r["aspect"] == a]
        report(f"  1:{a:<3d} geomean {_gm_time(sub):6.1f}%")
    report("by area per node (Fig. 16, modeled time):")
    for ar in AREAS:
        sub = [r for r in rows if r["area"] == ar]
        report(f"  {ar:.0e}  geomean {_gm_time(sub):6.1f}%")
    report("by machine size (Fig. 17, modeled time):")
    for g in GPUS:
        sub = [r for r in rows if r["gpus"] == g]
        report(f"  {g:4d} GPUs geomean {_gm_time(sub):6.1f}%")
    return {
        "n": len(rows), "max_pct": imps[-1], "min_pct": imps[0],
        "max_time_pct": timps[-1],
        "geomean_time_pct": _gm_time(rows), "rows": rows,
    }


def run(report=print) -> dict:
    out = {}
    for app in apps.iter_apps(pattern="halo"):
        out[app.name] = sweep_app(app, report)
    return out


if __name__ == "__main__":
    run()
