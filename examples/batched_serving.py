"""Continuous-batching serving: slot reuse, mixed prompt/output lengths.

Twelve requests with different prompt and generation lengths stream
through four cache slots — finished sequences release their slot
immediately (no tail-of-batch stragglers), the production pattern the
decode_32k dry-run shape sizes at 128 slots x 32k cache.

Run:  PYTHONPATH=src python examples/batched_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serving import ContinuousBatcher, Request


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(7)

    batcher = ContinuousBatcher(model, params, n_slots=4, max_len=64)
    reqs = []
    for i in range(12):
        r = Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16)),
            max_new_tokens=int(rng.integers(4, 12)),
        )
        reqs.append(r)
        batcher.submit(r)

    t0 = time.perf_counter()
    stats = batcher.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"{stats.completed} requests in {stats.steps} scheduler steps "
          f"({dt:.1f}s, {stats.tokens_out / dt:.1f} tok/s)")
    s = stats.summary()
    print(f"latency p50 {s['p50_latency_s']:.2f}s  "
          f"p95 {s['p95_latency_s']:.2f}s")
    for r in reqs[:4]:
        print(f"req {r.uid}: prompt {len(r.prompt)} toks -> "
              f"{r.generated}")


if __name__ == "__main__":
    main()
