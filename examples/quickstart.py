"""Quickstart: the Mapple DSL in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    GPU, Machine, block_mapper, cyclic_mapper, dsl,
    greedy_factorization, optimal_factorization, halo_surface_volume,
)

# ---------------------------------------------------------------- 1. spaces
# A machine is a processor space; primitives reshape it (paper Fig. 6).
m = Machine(GPU, shape=(2, 4))            # 2 nodes x 4 GPUs
print("machine:", m.shape)
m1 = m.merge(0, 1)                        # -> (8,)
m2 = m1.split(0, 2)                       # -> (2, 4) again (inverse)
print("merge+split roundtrip:", m2.shape,
      "identity:", all(m2.to_root((i, j)) == (i, j)
                       for i in range(2) for j in range(4)))

# ------------------------------------------------------------- 2. mapping
# A mapper sends iteration points to processors (paper Fig. 3).
b = block_mapper(m)
print("block2D grid on (4, 8):")
print(b.assignment_grid((4, 8)))
print("cyclic2D grid on (4, 8):")
print(cyclic_mapper(m).assignment_grid((4, 8)))

# ----------------------------------------------------------- 3. decompose
# The paper's key primitive: factor a processor count against the
# iteration space to minimize communication (Sec. 4).
lengths = (12, 18)
opt = optimal_factorization(6, lengths)
greedy = greedy_factorization(6, 2)       # Algorithm 1 (Chapel heuristic)
print(f"\niteration space {lengths}, 6 processors:")
print(f"  decompose -> {opt}, boundary elements ="
      f" {2 * halo_surface_volume(lengths, opt):.0f}")
print(f"  greedy    -> {greedy}, boundary elements ="
      f" {2 * halo_surface_volume(lengths, greedy):.0f}")

# ------------------------------------------------------ 4. textual mappers
prog = dsl.parse("""
m = Machine(GPU, shape=(2, 2))

def block2d(Tuple ipoint, Tuple ispace):
    idx = ipoint * m.size / ispace
    return m[*idx]

IndexTaskMap matmul block2d
Region matmul arg0 GPU FBMEM
Backpressure matmul 2
""")
p = prog.mappers["block2d"]((2, 3), (6, 6))
print(f"\nMapple program: {prog.loc()} LoC; block2d (2,3) -> "
      f"node {p.node}, gpu {p.proc}")

# ------------------------------------------------- 5. mesh-planner (LM use)
from repro.core.autosharder import LMWorkload, plan_mesh

wl = LMWorkload(global_batch=256, seq_len=4096, d_model=2048, n_layers=24,
                n_heads=32, n_kv_heads=8, param_count=2.5e9)
plan = plan_mesh(256, wl)
print(f"\n256 chips for a 2.5B LM -> dp={plan.dp} tp={plan.tp} "
      f"({plan.candidates_considered} candidates, "
      f"{plan.step_comm_bytes / 2**30:.1f} GiB/step modeled)")
