"""Distributed matmul with swappable Mapple mappers (paper Sec. 6.2).

Runs Cannon's algorithm under (a) the algorithm-specified hierarchical
mapper and (b) the runtime-heuristic mapper of Fig. 13, on 8 fake CPU
devices, and shows both give the right answer while permuting the devices
differently — the permutation is what changes the traffic pattern on a
real torus.

Run:  PYTHONPATH=src python examples/matmul_mappers.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GPU, Machine
from repro.core.commvolume import MatmulProblem, cannon_volume, summa_volume
from repro.matmul import cannon, johnson, runtime_heuristic_mapper, summa
from repro.matmul.common import build_grid, make_inputs

a, b = make_inputs(64, 64, 64, seed=0)
ref = np.asarray(a) @ np.asarray(b)
machine = Machine(GPU, shape=(2, 2))
devs = jax.devices()[:4]

print("=== Cannon's algorithm, two mappers ===")
g_spec = cannon.grid_for(machine, devs)
out = cannon.matmul(a, b, g_spec)
print("algorithm-specified mapper:",
      [d.id for d in g_spec.mesh.devices.flat],
      "max err", float(jnp.abs(out - ref).max()))

g_heur = build_grid(runtime_heuristic_mapper(machine), (2, 2), ("x", "y"),
                    devs)
out = cannon.matmul(a, b, g_heur)
print("runtime-heuristic mapper:  ",
      [d.id for d in g_heur.mesh.devices.flat],
      "max err", float(jnp.abs(out - ref).max()))

print("\n=== analytic communication volumes (elements) ===")
p = MatmulProblem(4096, 4096, 4096)
print(f"cannon  on (8,8):      {cannon_volume(p, (8, 8)):.3e}")
print(f"summa   on (8,8):      {summa_volume(p, (8, 8)):.3e}")

print("\n=== Johnson's 3D on 8 devices ===")
g3 = johnson.grid_for(Machine(GPU, shape=(8, 1)))
out = johnson.matmul(a, b, g3)
print("grid", g3.shape, "max err", float(jnp.abs(out - ref).max()))
