"""End-to-end driver: train a ~100M-param model for a few hundred steps.

smollm-135m at FULL config is ~135M params — small enough for CPU when we
shorten the sequence; this trains the real architecture (30 layers, GQA,
tied embeddings) with the real substrate: AdamW + cosine, synthetic-corpus
pipeline, async checkpointing, bounded-async dispatch, and a simulated
mid-run failure with restart.

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 300]
(Use --tiny for a quick smoke pass.)
"""
import argparse
import tempfile

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import make_pipeline
from repro.models import build
from repro.runtime import FailureInjector, SimulatedFailure
from repro.training import (
    AdamWConfig, TrainLoop, TrainState, init_state, make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (fast smoke)")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if args.tiny:
        cfg = cfg.reduced()
    model = build(cfg)
    print(f"training smollm-135m ({model.n_params:,} params) "
          f"for {args.steps} steps, batch {args.batch} x seq {args.seq}")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=args.steps // 10,
                          total_steps=args.steps)
    pipe = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    state = init_state(model, jax.random.key(0), opt_cfg)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, async_save=True)
        loop = TrainLoop(step_fn, pipe, backpressure=2,
                         checkpoint_manager=mgr,
                         save_every=max(args.steps // 4, 10))
        fail_step = args.steps // 2
        injector = FailureInjector(fail_at_steps=(fail_step,), max_failures=1)

        step = 0
        history = []
        while step < args.steps:
            try:
                def guarded(st, batch, _step=[step]):
                    return step_fn(st, batch)

                # run in segments so the injector can interrupt
                for s in range(step, args.steps):
                    injector.check(s)
                    state, metrics = step_fn(state, pipe.batch(s))
                    if s % 25 == 0:
                        print(f"step {s:4d} loss {float(metrics['loss']):.4f}")
                    history.append(float(metrics["loss"]))
                    if (s + 1) % loop.save_every == 0:
                        mgr.save(s + 1, state.as_tree(), {"cursor": s + 1})
                step = args.steps
            except SimulatedFailure as e:
                print(f"!! {e} — restoring latest checkpoint")
                mgr.wait()
                step, tree, _ = mgr.restore()
                state = TrainState.from_tree(tree)
                print(f"   resumed at step {step}")
        mgr.wait()

    print(f"loss: {history[0]:.4f} -> {history[-1]:.4f} "
          f"({len(history)} executed steps incl. replay)")
    assert history[-1] < history[0], "loss must decrease"


if __name__ == "__main__":
    main()
