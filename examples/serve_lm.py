"""Serving example: batched requests against a decode cache.

Builds a small model, then serves a batch of mixed-length "requests" with
a shared ring/linear cache: prefill each prompt, then decode new tokens
for the whole batch in lockstep — the batching pattern the decode_32k
dry-run shape exercises at scale.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build


def main() -> None:
    cfg = get_config("h2o-danube-1.8b").reduced()   # SWA ring-buffer cache
    model = build(cfg)
    params = model.init(jax.random.key(0))
    decode = jax.jit(model.decode_step)

    B, prompt_len, gen = 4, 24, 24
    max_len = prompt_len + gen
    prompts = jax.random.randint(
        jax.random.key(1), (B, prompt_len), 0, cfg.vocab_size
    )

    cache = model.init_cache(B, max_len)
    print(f"cache (ring buffer, window={cfg.sliding_window}):",
          {k: v.shape for k, v in cache.items()})

    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, cache = decode(params, cache, jnp.int32(t),
                               prompts[:, t:t + 1])
    print(f"prefill: {prompt_len} steps in {time.time() - t0:.2f}s")

    generated = []
    t0 = time.time()
    for t in range(prompt_len, max_len):
        nxt = jnp.argmax(logits.reshape(B, -1), axis=-1)
        nxt = jnp.clip(nxt, 0, cfg.vocab_size - 1).astype(jnp.int32)
        generated.append(nxt)
        logits, cache = decode(params, cache, jnp.int32(t), nxt[:, None])
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = jnp.stack(generated, axis=1)
    print(f"decoded {B}x{gen} tokens in {dt:.2f}s "
          f"({B * gen / dt:.1f} tok/s)")
    for b in range(B):
        print(f"request {b}: {toks[b].tolist()}")


if __name__ == "__main__":
    main()
